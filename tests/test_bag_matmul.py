"""Fused dequant-bag -> matmul kernel (repro.kernels.bag_matmul):
oracle equality, tiling invariance, the custom_vjp training twin vs
dense autodiff, the sharded serving path, and the model fused heads.

Numerical contract (kernel.py docstring): the fused kernel equals
exact fp32 sequential accumulation; K=1 bags are bit-identical to the
unfused oracle, multi-slot bags with non-unit weights may differ from
the dequant_bag path by 1 ulp (XLA FMA-contracts its accumulate), so
those comparisons are tight-allclose, not bitwise."""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import FQuantConfig, pack
from repro.core import packed_store as ps
from repro.core import qat_store as qs
from repro.kernels.bag_matmul.kernel import bag_matmul_pallas
from repro.kernels.bag_matmul.ops import packed_bag_matmul
from repro.kernels.bag_matmul.ref import bag_matmul_ref


def _case(b, k, d, h, v=64, seed=0):
    rng = np.random.default_rng(seed)
    payload = jnp.asarray(rng.integers(-128, 128, (v, d)).astype(np.int8))
    scales = jnp.asarray(rng.uniform(0.001, 0.02, v).astype(np.float32))
    idx = jnp.asarray(rng.integers(0, v, (b, k)).astype(np.int32))
    w = jnp.asarray(rng.uniform(0.1, 1.0, (b, k)).astype(np.float32))
    w3 = jnp.asarray(rng.standard_normal((k, d, h)).astype(np.float32)
                     * 0.1)
    return payload, scales, idx, w, w3


def _store_with_tiers(v=96, d=32, seed=0):
    st = qs.init(jax.random.PRNGKey(seed), v, d, scale=0.05)
    third = v // 3
    pri = jnp.concatenate([jnp.zeros(third), jnp.full(third, 1e4),
                           jnp.full(v - 2 * third, 1e6)])
    return st._replace(priority=pri)


def _packed(v=96, d=32, seed=0, table=None):
    cfg = FQuantConfig(stochastic=False)
    st = _store_with_tiers(v=v, d=d, seed=seed)
    if table is not None:
        st = st._replace(table=table)
    st = st._replace(table=qs.snap(
        st.table, qs.current_tiers(st, cfg), cfg))
    return pack(st, cfg)


@pytest.mark.parametrize("b,k,d,h", [(5, 3, 16, 8), (8, 1, 32, 4),
                                     (7, 4, 24, 10)])
def test_bag_matmul_matches_ref(b, k, d, h):
    payload, scales, idx, w, w3 = _case(b, k, d, h)
    out = bag_matmul_pallas(payload, scales, idx, w, w3)
    ref = bag_matmul_ref(payload, scales, idx, w, w3)
    assert out.shape == (b, h) and out.dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-6, atol=1e-7)


def test_bag_matmul_k1_bit_identical_to_ref():
    """Single-slot bags (the per-field serving layout): no accumulation
    across slots, so fused == unfused bit for bit."""
    payload, scales, idx, w, w3 = _case(9, 1, 16, 8, seed=3)
    out = bag_matmul_pallas(payload, scales, idx, w, w3)
    ref = bag_matmul_ref(payload, scales, idx, w, w3)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_bag_matmul_block_invariance():
    """(block_b, block_h) is a scheduling choice: every tiling —
    including non-dividing edge tiles — computes the same result, which
    is what makes the measured autotune cache safe to apply blindly.
    Tight-allclose, not bitwise: the per-tile dot's reduction order is
    backend-scheduled (CPU interpret lowers it to a gemm whose blocking
    varies with the tile shape)."""
    payload, scales, idx, w, w3 = _case(9, 3, 16, 12, seed=5)
    base = bag_matmul_pallas(payload, scales, idx, w, w3,
                             block_b=9, block_h=12)
    for bb, bh in ((2, 8), (4, 16), (7, 4), (1, 12)):
        out = bag_matmul_pallas(payload, scales, idx, w, w3,
                                block_b=bb, block_h=bh)
        np.testing.assert_allclose(np.asarray(out), np.asarray(base),
                                   rtol=2e-5, atol=1e-6)


def test_bag_matmul_scale_after():
    """int8-in specialization: rows enter the matmul unscaled and the
    per-slot (scale*weight) factor applies to the (B, H) result —
    valid only for K=1 bags, where the factor is per-row."""
    payload, scales, idx, w, w3 = _case(6, 1, 16, 8, seed=7)
    out = bag_matmul_pallas(payload, scales, idx, w, w3,
                            scale_after=True)
    ref = bag_matmul_ref(payload, scales, idx, w, w3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-6, atol=1e-7)


def test_packed_bag_matmul_fused_vs_unfused():
    """The acceptance gate: fused serving == unfused
    (lookup + reshape + matmul) within fp32 tolerance on a mixed-tier
    packed store, for 2d and 3d weight layouts and the int8-direct
    fast path."""
    packed = _packed()
    rng = np.random.default_rng(11)
    b, f, h = 9, 5, 12
    idx = jnp.asarray(rng.integers(0, packed.vocab, (b, f))
                      .astype(np.int32))
    w2 = jnp.asarray(rng.standard_normal((f * packed.dim, h))
                     .astype(np.float32) * 0.1)
    unfused = packed_bag_matmul(packed, idx, w2, use_pallas=False)
    for kwargs in ({}, {"int8_direct": True},):
        fused = packed_bag_matmul(packed, idx, w2, use_pallas=True,
                                  **kwargs)
        np.testing.assert_allclose(np.asarray(fused),
                                   np.asarray(unfused),
                                   rtol=1e-6, atol=1e-6)
    w3 = w2.reshape(f, packed.dim, h)
    fused3 = packed_bag_matmul(packed, idx, w3, use_pallas=True)
    np.testing.assert_allclose(np.asarray(fused3), np.asarray(unfused),
                               rtol=1e-6, atol=1e-6)
    # core wrapper is the same computation
    wrapped = ps.bag_matmul(packed, idx, w2, use_pallas=True)
    np.testing.assert_array_equal(
        np.asarray(wrapped),
        np.asarray(packed_bag_matmul(packed, idx, w2, use_pallas=True)))


def test_bag_matmul_train_gradcheck_vs_dense():
    """bag_matmul_train's custom_vjp (serving kernels in both passes)
    vs jnp dense autodiff: dtable, dw3 and dweights all match."""
    from repro.kernels.bag_matmul.autodiff import bag_matmul_train

    rng = np.random.default_rng(13)
    v, d, b, k, h = 32, 8, 6, 3, 5
    table = jnp.asarray(rng.standard_normal((v, d)).astype(np.float32))
    idx = jnp.asarray(rng.integers(0, v, (b, k)).astype(np.int32))
    w3 = jnp.asarray(rng.standard_normal((k, d, h)).astype(np.float32))
    wts = jnp.asarray(rng.uniform(0.1, 1.0, (b, k)).astype(np.float32))
    cot = jnp.asarray(rng.standard_normal((b, h)).astype(np.float32))

    def fused_loss(t, w, ww):
        return jnp.sum(bag_matmul_train(t, idx, w, ww,
                                        use_pallas=True) * cot)

    def dense_loss(t, w, ww):
        rows = jnp.take(t, idx, axis=0) * ww[..., None]
        return jnp.sum(jnp.einsum("bkd,kdh->bh", rows, w) * cot)

    got = jax.grad(fused_loss, argnums=(0, 1, 2))(table, w3, wts)
    want = jax.grad(dense_loss, argnums=(0, 1, 2))(table, w3, wts)
    for g, ref in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(ref),
                                   rtol=1e-4, atol=1e-5)


def test_bag_matmul_train_forward_is_serving_kernel():
    from repro.kernels.bag_matmul.autodiff import bag_matmul_train

    rng = np.random.default_rng(17)
    v, d, b, k, h = 32, 8, 6, 3, 5
    table = jnp.asarray(rng.standard_normal((v, d)).astype(np.float32))
    idx = jnp.asarray(rng.integers(0, v, (b, k)).astype(np.int32))
    w2 = jnp.asarray(rng.standard_normal((k * d, h)).astype(np.float32))
    out = bag_matmul_train(table, idx, w2, use_pallas=True)
    rows = jnp.take(table, idx, axis=0)
    ref = jnp.einsum("bkd,kdh->bh", rows, w2.reshape(k, d, h))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-6, atol=1e-6)


def test_sharded_bag_matmul_mesh1_matches_host():
    from repro.dist.packed import shard_packed, sharded_bag_matmul

    packed = _packed(seed=4)
    mesh = jax.make_mesh((1,), ("model",))
    sp = shard_packed(packed, mesh)
    rng = np.random.default_rng(19)
    b, f, h = 8, 4, 6
    idx = jnp.asarray(rng.integers(0, packed.vocab, (b, f))
                      .astype(np.int32))
    w = jnp.asarray(rng.standard_normal((f * packed.dim, h))
                    .astype(np.float32) * 0.1)
    wts = jnp.asarray(rng.uniform(0.1, 1.0, (b, f)).astype(np.float32))
    host = packed_bag_matmul(packed, idx, w, use_pallas=False)
    for use_pallas in (False, True):
        out = sharded_bag_matmul(sp, idx, w, mesh=mesh,
                                 use_pallas=use_pallas)
        np.testing.assert_allclose(np.asarray(out), np.asarray(host),
                                   rtol=2e-5, atol=2e-5)
    outw = sharded_bag_matmul(sp, idx, w, mesh=mesh, weights=wts,
                              use_pallas=True)
    hostw = packed_bag_matmul(packed, idx, w, weights=wts,
                              use_pallas=False)
    np.testing.assert_allclose(np.asarray(outw), np.asarray(hostw),
                               rtol=2e-5, atol=2e-5)


def test_sharded_bag_matmul_mesh4_matches_oracle():
    """4-way host mesh in a subprocess (device count must be set before
    jax init): psum'd (B, H) tiles vs the single-device oracle."""
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from repro.core import FQuantConfig, pack
from repro.core import qat_store as qs
from repro.dist.packed import shard_packed, sharded_bag_matmul
from repro.kernels.bag_matmul.ops import packed_bag_matmul

v, d = 96, 32
st = qs.init(jax.random.PRNGKey(0), v, d, scale=0.05)
third = v // 3
pri = jnp.concatenate([jnp.zeros(third), jnp.full(third, 1e4),
                       jnp.full(v - 2 * third, 1e6)])
st = st._replace(priority=pri)
cfg = FQuantConfig(stochastic=False)
st = st._replace(table=qs.snap(st.table, qs.current_tiers(st, cfg), cfg))
packed = pack(st, cfg)

mesh = jax.make_mesh((4,), ("model",))
sp = shard_packed(packed, mesh)
rng = np.random.default_rng(23)
b, f, h = 8, 4, 6
idx = jnp.asarray(rng.integers(0, v, (b, f)).astype(np.int32))
w = jnp.asarray(rng.standard_normal((f * d, h)).astype(np.float32) * 0.1)
wts = jnp.asarray(rng.uniform(0.1, 1.0, (b, f)).astype(np.float32))

for use_pallas in (False, True):
    for weights in (None, wts):
        out = sharded_bag_matmul(sp, idx, w, mesh=mesh, weights=weights,
                                 use_pallas=use_pallas)
        ref = packed_bag_matmul(packed, idx, w, weights=weights,
                                use_pallas=False)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-5, atol=2e-5)
out8 = sharded_bag_matmul(sp, idx, w, mesh=mesh, use_pallas=True,
                          int8_direct=True)
ref = packed_bag_matmul(packed, idx, w, use_pallas=False)
np.testing.assert_allclose(np.asarray(out8), np.asarray(ref),
                           rtol=2e-5, atol=2e-5)
print("SHARDED_BAGMM_OK")
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in ("src", env.get("PYTHONPATH", "")) if p)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env,
                       cwd=os.path.join(os.path.dirname(__file__), ".."))
    assert "SHARDED_BAGMM_OK" in r.stdout, r.stderr[-2000:]


def test_mlp_tail_invariant():
    """mlp(params, x) == mlp_tail(params, x @ w0) — the identity the
    fused heads rely on, for 1-layer and deep nets."""
    from repro.models import layers as L

    rng = np.random.default_rng(29)
    x = jnp.asarray(rng.standard_normal((5, 12)).astype(np.float32))
    for dims in ((12, 7), (12, 16, 8, 1)):
        params = L.mlp_init(jax.random.PRNGKey(1), dims, jnp.float32)
        full = L.mlp(params, x)
        tail = L.mlp_tail(params, x @ params["l0"]["w"])
        np.testing.assert_allclose(np.asarray(tail), np.asarray(full),
                                   rtol=1e-6, atol=1e-6)


def _packed_for_model(model, params, seed=0):
    v = model.spec.total_rows
    d = model.spec.dim
    return _packed(v=v, d=d, seed=seed, table=params["embed_table"])


def test_wide_deep_fused_head_matches_head():
    from repro.models import recsys

    model = recsys.make_wide_deep(recsys.WideDeepConfig(
        cardinalities=(40, 30, 50), embed_dim=8, mlp=(16, 8)))
    params = model.init(jax.random.PRNGKey(2))
    packed = _packed_for_model(model, params)
    rng = np.random.default_rng(31)
    b = 6
    idx = jnp.asarray(np.stack([rng.integers(0, c, b) for c in
                                (40, 30, 50)], axis=1).astype(np.int32))
    batch = {"indices": idx}
    gidx = jnp.asarray(np.asarray(idx)
                       + model.spec.offsets()[None, :])
    emb = ps.lookup(packed, gidx)
    assert model.extras["fused_needs_emb"] is False
    fused = model.extras["fused_head"](
        params, batch, lambda w: ps.bag_matmul(packed, gidx, w,
                                               use_pallas=True))
    unfused = model.head(params, emb, batch)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(unfused),
                               rtol=1e-5, atol=1e-6)


def test_xdeepfm_fused_head_matches_head():
    from repro.models import recsys

    model = recsys.make_xdeepfm(recsys.XDeepFMConfig(
        cardinalities=(30, 20), embed_dim=8, cin_layers=(6,),
        mlp=(12,)))
    params = model.init(jax.random.PRNGKey(3))
    packed = _packed_for_model(model, params, seed=1)
    rng = np.random.default_rng(37)
    b = 5
    idx = jnp.asarray(np.stack([rng.integers(0, c, b) for c in
                                (30, 20)], axis=1).astype(np.int32))
    batch = {"indices": idx}
    gidx = jnp.asarray(np.asarray(idx)
                       + model.spec.offsets()[None, :])
    emb = ps.lookup(packed, gidx)
    assert model.extras["fused_needs_emb"] is True
    fused = model.extras["fused_head"](
        params, batch, lambda w: ps.bag_matmul(packed, gidx, w,
                                               use_pallas=True), emb)
    unfused = model.head(params, emb, batch)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(unfused),
                               rtol=1e-5, atol=1e-6)
