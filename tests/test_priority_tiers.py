"""Eq. 7 priority EMA + Eq. 8 tier assignment + memory accounting."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
import hypothesis.strategies as st

from repro.core import (
    PriorityConfig,
    TierConfig,
    assign_tiers,
    batch_counts,
    compression_ratio,
    memory_bytes,
    priority_update,
    priority_update_from_batch,
    tier_counts,
)
from repro.core.tiers import plan_thresholds_for_ratio


def test_eq7_single_step():
    """w' = (1-b)*w + b*(a*c+ + c-), elementwise, paper constants."""
    cfg = PriorityConfig(alpha=2.0, beta=0.99)
    w = jnp.array([10.0, 0.0])
    c_pos = jnp.array([3.0, 0.0])
    c_neg = jnp.array([1.0, 5.0])
    w2 = priority_update(w, c_pos, c_neg, cfg)
    np.testing.assert_allclose(
        np.asarray(w2),
        [0.01 * 10 + 0.99 * (2 * 3 + 1), 0.99 * 5.0], rtol=1e-6)


def test_batch_counts_positive_negative():
    idx = jnp.array([[0, 1], [0, 2], [1, 1]])
    lab = jnp.array([1.0, 0.0, 1.0])
    c_pos, c_neg = batch_counts(idx, lab, vocab=4)
    np.testing.assert_allclose(np.asarray(c_pos), [1, 3, 0, 0])
    np.testing.assert_allclose(np.asarray(c_neg), [1, 0, 1, 0])


def test_untouched_rows_decay():
    cfg = PriorityConfig(beta=0.99)
    w = jnp.full((5,), 100.0)
    idx = jnp.array([[0]])
    lab = jnp.array([0.0])
    w2 = priority_update_from_batch(w, idx, lab, cfg)
    assert float(w2[4]) == 1.0  # (1-0.99)*100: decayed, no hits
    assert float(w2[0]) > float(w2[4])


def test_eq8_tiers_paper_thresholds():
    cfg = TierConfig(t8=1e3, t16=1e5)
    w = jnp.array([0.0, 999.0, 1000.0, 99999.0, 100000.0, 1e7])
    t = assign_tiers(w, cfg)
    np.testing.assert_array_equal(np.asarray(t), [0, 0, 1, 1, 2, 2])


def test_memory_accounting():
    # 10 int8 + 10 half + 10 fp32 rows of dim 16
    tiers = jnp.concatenate([jnp.zeros(10), jnp.ones(10),
                             jnp.full(10, 2)]).astype(jnp.int8)
    counts = tier_counts(tiers)
    np.testing.assert_array_equal(counts, [10, 10, 10])
    d = 16
    payload = 10 * d + 10 * 2 * d + 10 * 4 * d
    overhead = 20 * 4 + 30 * 4
    assert memory_bytes(tiers, d) == payload + overhead
    assert memory_bytes(tiers, d, include_overhead=False) == payload


def test_compression_ratio_limits():
    d = 64
    all8 = jnp.zeros(1000, jnp.int8)
    all32 = jnp.full(1000, 2, jnp.int8)
    assert compression_ratio(all8, d) < 0.3     # ~0.25 + overhead
    assert 0.99 < compression_ratio(all32, d) < 1.05


@given(st.floats(0.3, 1.0), st.integers(0, 100))
@settings(max_examples=20, deadline=None, derandomize=True)
def test_threshold_planner_hits_budget(target, seed):
    """plan_thresholds_for_ratio lands within ~20% of the byte budget
    (it is a quantile heuristic; ties at the cut under heavy-tailed
    priorities shift the landed budget by up to one tier width)."""
    w = jnp.asarray(np.random.default_rng(seed).lognormal(0, 3, 4096)
                    .astype(np.float32))
    cfg = plan_thresholds_for_ratio(w, dim=64, target_ratio=target)
    tiers = assign_tiers(w, cfg)
    got = memory_bytes(tiers, 64, include_overhead=False) / (4096 * 64 * 4)
    assert abs(got - target) < 0.2


def test_paper_50pct_configuration():
    """Zipf-ish priorities + paper thresholds give roughly the paper's
    ~50% memory (sanity on the running example, not a strict claim)."""
    rng = np.random.default_rng(0)
    # heavy-tailed: most rows cold (int8), some warm, few hot
    w = jnp.asarray((rng.pareto(1.0, 100000) * 30).astype(np.float32))
    tiers = assign_tiers(w, TierConfig(t8=1e3, t16=1e5))
    ratio = compression_ratio(tiers, 64)
    assert ratio < 0.6
