"""End-to-end compression pipeline: compressed train step, in-training
Taylor/access accumulation, and the train->prune->quantize->pack->serve
driver with its bench_pipeline/v1 record."""

import importlib.util
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt import CheckpointManager
from repro.configs import dlrm_rm2
from repro.core import taylor
from repro.core.qat_store import FQuantConfig
from repro.data.criteo import CriteoConfig, CriteoSynth
from repro.models import embedding as E
from repro.train import accum as accum_lib
from repro.train.steps import make_compressed_train_step


def _setup():
    arch = dlrm_rm2.arch()
    model = arch.smoke_model
    spec = model.spec
    ds = CriteoSynth(CriteoConfig(
        num_fields=spec.num_fields,
        cardinalities=tuple(int(c) for c in spec.cardinalities),
        num_dense=arch.smoke_num_dense,
        important_fields=spec.num_fields // 2))
    return model, spec, ds


def _make_step(model, spec, **kw):
    return make_compressed_train_step(
        model.loss_from_emb,
        lambda b: E.globalize(b["indices"], spec),
        lambda b: b["labels"],
        "embed_table", 0.05, spec.num_fields,
        fq_cfg=FQuantConfig(stochastic=False), use_pallas=False, **kw)


def _jbatch(ds, n, s):
    return {k: jnp.asarray(v) for k, v in ds.batch(n, s).items()}


def test_compressed_step_trains_and_accumulates():
    model, spec, ds = _setup()
    step = _make_step(model, spec)
    state = step.init_state(model.init(jax.random.PRNGKey(0)))
    jstep = jax.jit(step)
    losses = []
    for i in range(8):
        state, m = jstep(state, _jbatch(ds, 32, i))
        losses.append(float(m["loss"]))
    assert all(np.isfinite(losses))
    assert min(losses[4:]) < losses[0]
    acc = state.accum
    assert float(acc.count) == 8 * 32
    touched = np.asarray(acc.access) > 0
    assert 0 < touched.sum() < spec.total_rows
    # the Eq. 7 fold ran: priority and access EMAs agree on support
    pri = np.asarray(state.priority)
    np.testing.assert_array_equal(pri > 0, touched)
    # fquant snap ran: int-tier rows sit on their quantization grid
    assert int(state.step) == 8


def test_accum_matches_offline_taylor_scores():
    """One batch of update_accum with a frozen mean reproduces the
    offline F-Permutation per-batch score (taylor._batch_scores_first)
    exactly — the in-training fold is the same Eq. 4."""
    model, spec, ds = _setup()
    params = model.init(jax.random.PRNGKey(1))
    batch = _jbatch(ds, 16, 0)
    moments = taylor.field_moments(
        lambda p, b: model.embed(p, b), params, [batch])
    ref_scores, _ = jax.jit(lambda p, b: taylor._batch_scores_first(
        p, b, moments.mean, lambda pp, bb: model.embed(pp, bb),
        model.loss_from_emb))(params, batch)

    emb, vjp = jax.vjp(lambda p: model.embed(p, batch), params)
    loss, g_emb = jax.value_and_grad(
        lambda e: model.loss_from_emb(params, e, batch).sum())(emb)
    acc = accum_lib.init_accum(spec.total_rows, spec.num_fields,
                               spec.dim)
    acc = acc._replace(emb_mean=moments.mean,
                       count=jnp.asarray(1.0))  # frozen, pre-seeded mean
    gidx = E.globalize(batch["indices"], spec)
    acc2 = accum_lib.update_accum(acc, gidx, emb, g_emb)
    np.testing.assert_allclose(np.asarray(acc2.field_score),
                               np.asarray(ref_scores), rtol=1e-5,
                               atol=1e-6)
    # and the access fold is priority.serve_update's
    from repro.core.priority import serve_update
    np.testing.assert_array_equal(
        np.asarray(acc2.access),
        np.asarray(serve_update(acc.access, gidx)))


def test_field_mask_zeroes_pruned_gradients():
    model, spec, ds = _setup()
    mask = np.ones(spec.num_fields, np.float32)
    mask[2] = 0.0
    step = _make_step(model, spec, field_mask=jnp.asarray(mask))
    state = step.init_state(model.init(jax.random.PRNGKey(0)))
    table0 = np.asarray(state.params["embed_table"])
    state, _ = jax.jit(step)(state, _jbatch(ds, 32, 0))
    table1 = np.asarray(state.params["embed_table"])
    off = spec.offsets()
    lo, hi = int(off[2]), int(off[2]) + int(spec.cardinalities[2])
    # masked field's rows receive no gradient; F-Quant snap (RTN, grid
    # projection) may still requantize them, but identical inputs under
    # an unchanged tier stay identical -> compare against a no-grad
    # snap of the original rows
    changed = np.abs(table1[lo:hi] - table0[lo:hi]).max()
    untouched_elsewhere = np.abs(table1 - table0).max()
    assert untouched_elsewhere > 0          # training moved something
    assert changed <= 1e-3                  # only snap-level movement


def test_train_state_with_accum_roundtrips_checkpoint(tmp_path):
    model, spec, ds = _setup()
    step = _make_step(model, spec)
    state = step.init_state(model.init(jax.random.PRNGKey(0)))
    jstep = jax.jit(step)
    for i in range(3):
        state, _ = jstep(state, _jbatch(ds, 16, i))
    mgr = CheckpointManager(str(tmp_path), keep=2)
    mgr.save(3, state)
    restored, s = mgr.restore(state)
    assert s == 3
    for a, b in zip(jax.tree_util.tree_leaves(jax.device_get(state)),
                    jax.tree_util.tree_leaves(restored)):
        aa, bb = np.asarray(a), np.asarray(b)
        assert aa.dtype == bb.dtype
        assert aa.tobytes() == bb.tobytes()


def test_compressed_step_mesh2_equivalent():
    """mesh=2 training (sharded table + per-shard custom_vjp kernels)
    is step-for-step equivalent to single-device training."""
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import dlrm_rm2
from repro.core.qat_store import FQuantConfig
from repro.data.criteo import CriteoConfig, CriteoSynth
from repro.models import embedding as E
from repro.train.steps import make_compressed_train_step

arch = dlrm_rm2.arch()
model, spec = arch.smoke_model, arch.smoke_model.spec
ds = CriteoSynth(CriteoConfig(
    num_fields=spec.num_fields,
    cardinalities=tuple(int(c) for c in spec.cardinalities),
    num_dense=arch.smoke_num_dense,
    important_fields=spec.num_fields // 2))
mesh = jax.make_mesh((2,), ("model",))

def make(m):
    return make_compressed_train_step(
        model.loss_from_emb,
        lambda b: E.globalize(b["indices"], spec),
        lambda b: b["labels"],
        "embed_table", 0.05, spec.num_fields,
        fq_cfg=FQuantConfig(stochastic=False), mesh=m,
        use_pallas=False)

s1 = make(None).init_state(model.init(jax.random.PRNGKey(0)))
s2 = make(mesh).init_state(model.init(jax.random.PRNGKey(0)))
rows2 = NamedSharding(mesh, P("model", None))
rows1 = NamedSharding(mesh, P("model"))
p = dict(s2.params); p["embed_table"] = jax.device_put(p["embed_table"], rows2)
s2 = s2._replace(params=p,
                 opt=(s2.opt[0], jax.device_put(s2.opt[1], rows1)),
                 priority=jax.device_put(s2.priority, rows1),
                 accum=s2.accum._replace(
                     access=jax.device_put(s2.accum.access, rows1)))
j1, j2 = jax.jit(make(None)), jax.jit(make(mesh))
for i in range(3):
    b = {k: jnp.asarray(v) for k, v in ds.batch(16, i).items()}
    s1, m1 = j1(s1, b)
    s2, m2 = j2(s2, b)
np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]), rtol=1e-5)
np.testing.assert_allclose(np.asarray(s1.params["embed_table"]),
                           np.asarray(s2.params["embed_table"]),
                           rtol=1e-5, atol=1e-6)
np.testing.assert_allclose(np.asarray(s1.priority),
                           np.asarray(s2.priority), rtol=1e-5, atol=1e-7)
np.testing.assert_allclose(np.asarray(s1.accum.field_score),
                           np.asarray(s2.accum.field_score),
                           rtol=1e-4, atol=1e-6)
print("MESH_TRAIN_OK")
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in ("src", env.get("PYTHONPATH", "")) if p)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env,
                       cwd=os.path.join(os.path.dirname(__file__), ".."))
    assert "MESH_TRAIN_OK" in r.stdout, r.stderr[-2000:]


# ------------------------------------------------------------ driver

def _load_schema_checker():
    path = os.path.join(os.path.dirname(__file__), "..", "tools",
                        "check_bench_schema.py")
    spec_ = importlib.util.spec_from_file_location("check_bench_schema",
                                                   path)
    mod = importlib.util.module_from_spec(spec_)
    spec_.loader.exec_module(mod)
    return mod


def test_run_pipeline_fast_record_valid(tmp_path):
    """The one-command driver end to end at test scale: every verify
    flag true, record passes the bench_pipeline/v1 validator."""
    from repro.launch.pipeline import fast_config, run_pipeline

    cfg = fast_config(steps=8, batch=16, ckpt_every=4,
                      finetune_steps=2, serve_requests=12,
                      retier_every=6, eval_batches=2,
                      ckpt_dir=str(tmp_path))
    rec = run_pipeline(cfg)
    assert rec["verify_pack_bit_identical"] is True
    assert rec["verify_serve_bit_identical"] is True
    assert rec["verify_grad_fp32_tolerance"] is True
    assert rec["verify_accum_checkpointed"] is True
    assert rec["bytes_packed"] < rec["bytes_fp32"]
    assert 0 <= rec["fields_pruned"] < rec["fields_total"]
    checker = _load_schema_checker()
    assert checker.validate(rec) == []
    # checkpoints on disk carry the accumulator (restartable pipeline)
    mgr = CheckpointManager(os.path.join(str(tmp_path), "train"))
    assert mgr.latest_step() == 8
