"""Embedding substrate + data generators."""

import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from repro.data.criteo import CriteoConfig, CriteoSynth
from repro.data.graphs import molecule_batch, padded_subgraph, random_graph
from repro.models import embedding as E


def test_field_spec_padding_and_offsets():
    spec = E.FieldSpec((100, 200, 300), 8)
    assert spec.total_rows % 512 == 0
    assert spec.total_rows >= 600
    np.testing.assert_array_equal(spec.offsets(), [0, 100, 300])


def test_globalize_and_lookup_respect_fields():
    spec = E.FieldSpec((10, 20), 4, pad_to=8)
    table = jnp.arange(spec.total_rows * 4, dtype=jnp.float32
                       ).reshape(-1, 4)
    idx = jnp.array([[3, 5]])
    emb = E.field_lookup(table, idx, spec)
    np.testing.assert_array_equal(np.asarray(emb[0, 0]),
                                  np.asarray(table[3]))
    np.testing.assert_array_equal(np.asarray(emb[0, 1]),
                                  np.asarray(table[10 + 5]))


def test_field_mask_zeroes_pruned():
    spec = E.FieldSpec((10, 10), 4, pad_to=8)
    table = jnp.ones((spec.total_rows, 4))
    emb = E.field_lookup(table, jnp.array([[1, 1]]), spec,
                         field_mask=jnp.array([1.0, 0.0]))
    assert float(emb[0, 0].sum()) == 4.0
    assert float(emb[0, 1].sum()) == 0.0


@given(st.integers(1, 50), st.integers(1, 8), st.integers(0, 100))
@settings(max_examples=20, deadline=None)
def test_embedding_bag_modes(n_idx, n_bags, seed):
    rng = np.random.default_rng(seed)
    table = jnp.asarray(rng.standard_normal((32, 4)).astype(np.float32))
    idx = jnp.asarray(rng.integers(0, 32, n_idx))
    seg = jnp.asarray(np.sort(rng.integers(0, n_bags, n_idx)))
    s = E.embedding_bag(table, idx, seg, n_bags, "sum")
    m = E.embedding_bag(table, idx, seg, n_bags, "mean")
    rows = np.asarray(table)[np.asarray(idx)]
    segs = np.asarray(seg)
    for b in range(n_bags):
        expect = rows[segs == b].sum(axis=0) if (segs == b).any() \
            else np.zeros(4)
        np.testing.assert_allclose(np.asarray(s[b]), expect, rtol=1e-5,
                                   atol=1e-6)
        cnt = max((segs == b).sum(), 1)
        np.testing.assert_allclose(np.asarray(m[b]), expect / cnt,
                                   rtol=1e-5, atol=1e-6)


def test_one_hot_matmul_equals_take():
    table = jax.random.normal(jax.random.PRNGKey(0), (16, 8))
    idx = jnp.array([3, 3, 7])
    np.testing.assert_allclose(
        np.asarray(E.one_hot_matmul_lookup(table, idx)),
        np.asarray(jnp.take(table, idx, axis=0)), rtol=1e-6)


def test_hash_indices_in_range_and_deterministic():
    ids = jnp.arange(10000)
    h1 = E.hash_indices(ids, 128)
    h2 = E.hash_indices(ids, 128)
    assert int(h1.min()) >= 0 and int(h1.max()) < 128
    np.testing.assert_array_equal(np.asarray(h1), np.asarray(h2))
    # roughly uniform occupancy
    counts = np.bincount(np.asarray(h1), minlength=128)
    assert counts.min() > 0


def test_criteo_determinism_and_planted_truth():
    ds1 = CriteoSynth(CriteoConfig(num_fields=6, important_fields=3,
                                   seed=9))
    ds2 = CriteoSynth(CriteoConfig(num_fields=6, important_fields=3,
                                   seed=9))
    b1, b2 = ds1.batch(128, 7), ds2.batch(128, 7)
    np.testing.assert_array_equal(b1["indices"], b2["indices"])
    np.testing.assert_array_equal(b1["labels"], b2["labels"])
    assert len(ds1.lossless_fields()) == 3
    assert (np.abs(ds1.field_weight) > 0).sum() == 3
    # zipf: row 0 is the most frequent
    idx = np.concatenate([ds1.batch(1024, s)["indices"][:, 0]
                          for s in range(5)])
    counts = np.bincount(idx)
    assert counts[0] == counts.max()


def test_graph_block_indices_closed():
    g = random_graph(300, 6, 8, seed=1)
    blk = padded_subgraph(g, np.arange(16), (4, 2), seed=2)
    n = blk["node_ids"].shape[0]
    assert blk["src"].max() < n and blk["dst"].max() < n
    assert blk["seed_local"].max() < n
    assert blk["labels"].shape == (16,)


def test_molecule_block_diagonal():
    mb = molecule_batch(4, 10, 20, 8, seed=3)
    for i in range(4):
        sel = slice(i * 20, (i + 1) * 20)
        assert (mb["src"][sel] >= i * 10).all()
        assert (mb["src"][sel] < (i + 1) * 10).all()
