"""Online serving over the hierarchical store: batch-for-batch
bit-identity with a fully device-resident OnlineServer under drift,
correct hit/miss accounting when lookups resolve from the warm/cold
levels, and promotion of pressured rows into device HBM within one
re-tier cadence."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import FQuantConfig
from repro.core import qat_store as qs
from repro.core.tiers import TierConfig
from repro.serve import (
    MicroBatcher,
    OnlineConfig,
    OnlineServer,
    cached_lookup,
    drifting_zipf_batch,
)
from repro.store import HOT, HierConfig
from repro.store.hier import combine_rows

V, D = 160, 24
CFG = FQuantConfig(tiers=TierConfig(t8=5.0, t16=50.0), stochastic=False)


def _store(seed=0):
    rng = np.random.default_rng(seed)
    st = qs.init(jax.random.PRNGKey(seed), V, D, scale=0.05)
    pri = jnp.asarray((rng.pareto(1.2, V) * 20).astype(np.float32))
    st = st._replace(priority=pri)
    return st._replace(table=qs.snap(
        st.table, qs.current_tiers(st, CFG), CFG))


def _hier_cfg(tmp_path, st, frac=8):
    from repro.core import pack
    b = pack(st, CFG).nbytes() // frac
    return HierConfig(hbm_budget_bytes=b, host_budget_bytes=b,
                      rows_per_shard=16,
                      store_dir=str(tmp_path / "cold"))


def _hier_rows(srv, idx, valid):
    """The serve_forward_hier inner math, minus the model head:
    stage -> combine -> cache-first select.  Returns (rows, hits)."""
    from repro.serve.cache import cache_select

    g = np.asarray(idx, np.int64)
    sb = srv.hier.stage(g, skip=srv.cache_mask[g],
                        valid=valid[:, None])
    rows = combine_rows(srv.hier.hot_dev, sb.hot_local, sb.stage_slot,
                        sb.staging, srv.lookup_fn())
    emb, hits = cache_select(srv.cache, jnp.asarray(idx), rows,
                             valid=jnp.asarray(valid)[:, None])
    return emb, int(hits)


def test_hier_serving_matches_flat_serving_under_drift(tmp_path):
    """Drive the SAME drifting-zipf micro-batch stream through a
    hierarchical server and a fully resident one: served rows are
    bit-identical every batch, priorities and re-tier cadence stay in
    lockstep, and the hier miss accounting is internally consistent."""
    st = _store(1)
    online = OnlineConfig(cache_rows=24, retier_every=8)
    flat = OnlineServer(st, CFG, online)
    hsrv = OnlineServer(st, CFG, online, hier=_hier_cfg(tmp_path, st))
    assert hsrv.hier.cold_ids.size > 0

    batcher = MicroBatcher(4, 2)
    mbs = []
    for r in range(22):
        mb = batcher.add(
            drifting_zipf_batch((V, V), 1, r, 22, drift=2.0, seed=3)[0])
        if mb is not None:
            mbs.append(mb)
    tail = batcher.flush()
    if tail is not None:
        mbs.append(tail)

    for mb in mbs:
        idx = jnp.asarray(mb.indices)
        ref, fhits = cached_lookup(flat.packed, flat.cache, idx,
                                   flat.lookup_fn(),
                                   valid=jnp.asarray(mb.valid)[:, None])
        got, hhits = _hier_rows(hsrv, mb.indices, mb.valid)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))
        assert hhits == int(fhits)          # same cache, same hits
        flat.observe(idx, int(fhits), valid=mb.valid[:, None],
                     count=mb.count)
        hsrv.observe(idx, hhits, valid=mb.valid[:, None],
                     count=mb.count)

    np.testing.assert_array_equal(np.asarray(flat.store.priority),
                                  np.asarray(hsrv.store.priority))
    assert flat.stats.retiers == hsrv.stats.retiers == 2
    assert flat.stats.requests == hsrv.stats.requests == 22
    assert flat.stats.lookups == hsrv.stats.lookups == 44
    assert flat.stats.hits == hsrv.stats.hits
    # hit accounting stays correct with warm/cold misses in the mix:
    # every valid lookup resolved from exactly one place
    hs = hsrv.hier.stats
    spilled = hs.warm_hits + hs.cold_hits
    assert 0 < spilled <= hsrv.stats.lookups - hsrv.stats.hits
    device = hsrv.stats.lookups - hsrv.stats.hits - spilled
    assert device >= 0
    assert hs.migrations == hsrv.stats.retiers


def test_pressured_rows_promoted_within_one_cadence(tmp_path):
    """Rows served from the cold level climb the Eq. 7 EMA and become
    device-resident at the next re-tier boundary."""
    st = _store(2)
    hsrv = OnlineServer(st, CFG,
                        OnlineConfig(cache_rows=0, retier_every=4),
                        hier=_hier_cfg(tmp_path, st))
    hammered = hsrv.hier.cold_ids[:3].copy()
    assert (hsrv.hier.level[hammered] != HOT).all()

    idx = np.tile(hammered, 2)[:6].reshape(3, 2).astype(np.int64)
    valid = np.ones(3, bool)
    for _ in range(2):                      # 2 batches x count=2 -> 4 req
        rows, hits = _hier_rows(hsrv, idx, valid)
        jax.block_until_ready(rows)
        hsrv.observe(jnp.asarray(idx), hits, valid=valid[:, None],
                     count=2)
    assert hsrv.stats.retiers == 1          # one cadence elapsed
    assert (hsrv.hier.level[hammered] == HOT).all()
    assert np.isin(hammered, hsrv.hier.hot_ids).all()
    assert hsrv.hier.stats.promoted >= 3
    # ... and they now resolve on-device: no new cold hits
    before = hsrv.hier.stats.cold_hits
    rows, _ = _hier_rows(hsrv, idx, valid)
    jax.block_until_ready(rows)
    assert hsrv.hier.stats.cold_hits == before


def test_cache_skip_keeps_values_and_traffic_split(tmp_path):
    """A warm/cold row resident in the fp32 cache is served from the
    cache (no staging traffic), bit-identically."""
    st = _store(3)
    hsrv = OnlineServer(st, CFG,
                        OnlineConfig(cache_rows=32, retier_every=0),
                        hier=_hier_cfg(tmp_path, st))
    cached_spill = np.asarray(hsrv.cache.ids)[
        np.nonzero(hsrv.hier.level[np.asarray(hsrv.cache.ids)]
                   != HOT)[0]]
    assert cached_spill.size > 0            # cache reaches past HBM
    idx = np.tile(cached_spill[:2], 2).reshape(2, 2)
    valid = np.ones(2, bool)
    before = hsrv.hier.stats.staged_rows
    rows, hits = _hier_rows(hsrv, idx, valid)
    assert hits == 4                        # every position a cache hit
    assert hsrv.hier.stats.staged_rows == before   # nothing staged
    np.testing.assert_array_equal(
        np.asarray(rows),
        hsrv.hier.gather_fp32_host(idx))


def test_loop_result_carries_hier_stats(tmp_path):
    """serve_forward_hier merges the hier counters into the record the
    drivers/benchmarks serialize."""
    from repro.serve.loop import LoopResult

    st = _store(4)
    hsrv = OnlineServer(st, CFG,
                        OnlineConfig(cache_rows=8, retier_every=0),
                        hier=_hier_cfg(tmp_path, st))
    idx = np.stack([hsrv.hier.warm_ids[:2],
                    hsrv.hier.cold_ids[:2]]).astype(np.int64)
    rows, hits = _hier_rows(hsrv, idx, np.ones(2, bool))
    hsrv.observe(jnp.asarray(idx), hits, count=2)
    stats = {**hsrv.stats.as_dict(), **hsrv.hier.stats.as_dict()}
    res = LoopResult(lat_s=(0.1,), qps=1.0, steady_qps=1.0,
                     p50_us=1.0, p95_us=1.0, p99_us=1.0,
                     p99_retier_attributed=0.0,
                     p99_while_retiering=0.0, stats=stats)
    d = res.as_dict()
    for key in ("warm_hits", "cold_hits", "staged_rows", "promoted",
                "demoted", "cache_hit_rate", "latency_p50",
                "latency_p95", "latency_p99", "p99_retier_attributed",
                "p99_while_retiering"):
        assert key in d
