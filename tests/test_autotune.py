"""Measured autotune cache: persistence, fallback and precedence.

The contract under test (repro.kernels.autotune + the resolve_*
layers): the serving path only ever READS the cache; anything wrong
with the file — missing, corrupt, wrong schema, malformed entry,
foreign key — degrades to the analytic pick, never to an error; and
explicit/env overrides always beat a cache hit."""

import importlib.util
import json
import pathlib

import pytest

from repro.kernels import autotune
from repro.kernels.dequant_bag.ops import (
    _auto_block_d,
    resolve_block_sizes,
)


@pytest.fixture
def cache(tmp_path, monkeypatch):
    path = tmp_path / "autotune.json"
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", str(path))
    return path


def test_store_lookup_roundtrip_preserves_entries(cache):
    autotune.store("dequant_bag", "int8", 64, 8, 64, 16, 64, 123.4)
    assert autotune.lookup_cached("dequant_bag", "int8",
                                  64, 8, 64) == (16, 64)
    doc = json.loads(cache.read_text())
    assert doc["schema"] == "autotune_cache/v1"
    # a second store merges: the first entry survives
    autotune.store("dequant_bag", "int8", 32, 4, 96, 8, 96, 50.0)
    assert autotune.lookup_cached("dequant_bag", "int8",
                                  64, 8, 64) == (16, 64)
    assert autotune.lookup_cached("dequant_bag", "int8",
                                  32, 4, 96) == (8, 96)


def test_resolve_serves_cache_hit(cache):
    b, k, d = 64, 8, 64
    analytic = resolve_block_sizes(b, k, d, 1)
    tuned = (max(1, analytic[0] // 2), analytic[1])
    assert tuned != analytic
    autotune.store("dequant_bag", "int8", b, k, d, *tuned, 1.0)
    assert resolve_block_sizes(b, k, d, 1) == tuned


def test_key_mismatch_is_a_miss_not_a_stale_hit(cache):
    b, k, d = 64, 8, 64
    analytic = resolve_block_sizes(b, k, d, 1)
    autotune.store("dequant_bag", "int8", b, k, d, 2, 32, 1.0)
    # different shape / kind / dtype: every probe misses and the
    # resolver re-derives the analytic pick instead of serving (2, 32)
    assert autotune.lookup_cached("dequant_bag", "int8",
                                  b, k, d + 1) is None
    assert autotune.lookup_cached("bag_grad", "float32", b, k, d) is None
    assert autotune.lookup_cached("dequant_bag", "bfloat16",
                                  b, k, d) is None
    assert resolve_block_sizes(b, k, d + 64, 1) == \
        resolve_block_sizes(b, k, d + 64, 1, block_b=None)
    assert resolve_block_sizes(b, k, d, 1, kind="bag_grad") == analytic


@pytest.mark.parametrize("content", [
    "not json {",
    json.dumps({"schema": "autotune_cache/v999", "entries": {}}),
    json.dumps(["a", "list"]),
    json.dumps({"schema": "autotune_cache/v1", "entries": "nope"}),
])
def test_corrupt_or_stale_cache_falls_back(cache, content):
    b, k, d = 64, 8, 64
    analytic = resolve_block_sizes(b, k, d, 1)
    cache.write_text(content)
    assert autotune.lookup_cached("dequant_bag", "int8", b, k, d) is None
    assert resolve_block_sizes(b, k, d, 1) == analytic


def test_malformed_entry_is_a_miss(cache):
    b, k, d = 64, 8, 64
    key = autotune.cache_key("dequant_bag", "int8", b, k, d)
    cache.write_text(json.dumps({
        "schema": "autotune_cache/v1",
        "entries": {key: {"block_b": "four", "block_d": 0}},
    }))
    assert autotune.lookup_cached("dequant_bag", "int8", b, k, d) is None
    assert resolve_block_sizes(b, k, d, 1) == \
        resolve_block_sizes(b, k, d, 1, block_b=None, block_d=None)


def test_env_override_wins_over_cache(cache, monkeypatch):
    b, k, d = 64, 8, 64
    autotune.store("dequant_bag", "int8", b, k, d, 2, 32, 1.0)
    assert resolve_block_sizes(b, k, d, 1) == (2, 32)
    monkeypatch.setenv("REPRO_DEQUANT_BLOCK_B", "4")
    # ANY pinned dimension disqualifies the jointly-tuned cache pair:
    # D must come back analytic, not the cached 32
    assert resolve_block_sizes(b, k, d, 1) == (4, _auto_block_d(d))
    monkeypatch.setenv("REPRO_DEQUANT_BLOCK_D", "16")
    assert resolve_block_sizes(b, k, d, 1) == (4, 16)
    monkeypatch.delenv("REPRO_DEQUANT_BLOCK_B")
    bb, bd = resolve_block_sizes(b, k, d, 1)
    assert bd == 16 and bb != 2  # B re-sized against env D, cache out


def test_explicit_args_win_over_everything(cache, monkeypatch):
    b, k, d = 64, 8, 64
    autotune.store("dequant_bag", "int8", b, k, d, 2, 32, 1.0)
    monkeypatch.setenv("REPRO_DEQUANT_BLOCK_B", "4")
    monkeypatch.setenv("REPRO_DEQUANT_BLOCK_D", "16")
    assert resolve_block_sizes(b, k, d, 1, block_b=8, block_d=64) == \
        (8, 64)


def test_empty_env_disables_cache(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_AUTOTUNE_CACHE", "")
    assert autotune.cache_path() is None
    assert autotune.store("dequant_bag", "int8", 8, 2, 32, 1, 32,
                          1.0) is None
    assert autotune.lookup_cached("dequant_bag", "int8", 8, 2,
                                  32) is None


def test_external_write_picked_up_without_restart(cache):
    """A sweep seeded by another process (direct file write) is served
    after the mtime changes — no in-process store() call needed."""
    b, k, d = 64, 8, 64
    assert autotune.lookup_cached("dequant_bag", "int8", b, k, d) is None
    key = autotune.cache_key("dequant_bag", "int8", b, k, d)
    cache.write_text(json.dumps({
        "schema": "autotune_cache/v1",
        "entries": {key: {"block_b": 4, "block_d": 64, "us": 9.0}},
    }))
    assert autotune.lookup_cached("dequant_bag", "int8",
                                  b, k, d) == (4, 64)


def test_bag_matmul_key_folds_output_width(cache):
    from repro.kernels.bag_matmul.ops import resolve_bm_block_sizes
    b, k, d, h = 64, 8, 64, 32
    autotune.store("bag_matmul", "int8", b, k, d, 8, 16, 1.0,
                   extra=f"|h={h}")
    assert resolve_bm_block_sizes(b, k, d, h, 1) == (8, 16)
    # same (b, k, d) with a different H is a distinct key: miss
    analytic = resolve_bm_block_sizes(b, k, d, 2 * h, 1)
    assert analytic != (8, 16)


def test_candidate_tilings_lead_with_analytic(cache):
    b, k, d = 64, 8, 64
    cands = autotune.candidate_tilings(b, k, d, 1)
    assert cands[0] == resolve_block_sizes(b, k, d, 1)
    assert len(cands) == len(set(cands))
    assert all(1 <= bb <= b and bd >= 1 for bb, bd in cands)


def test_sweep_skips_failing_candidates():
    calls = []

    def run(bb, bd):
        def thunk():
            calls.append((bb, bd))
            if bb == 2:
                raise ValueError("backend rejected tiling")
            import jax.numpy as jnp
            return jnp.zeros(())
        return thunk

    res = autotune.sweep(run, [(1, 8), (2, 8), (4, 8)], iters=1)
    assert res["best"] in {(1, 8), (4, 8)}
    failed = [r for r in res["sweep"] if r["us"] is None]
    assert [(r["block_b"], r["block_d"]) for r in failed] == [(2, 8)]


def test_kernel_bench_record_validates(cache):
    """benchmarks/kernels.py end to end at a tiny shape: the emitted
    record passes the bench_kernel/v1 validator, holds the
    measured<=analytic invariant, and --seed-cache entries resolve."""
    root = pathlib.Path(__file__).resolve().parent.parent

    def _load(name, rel):
        spec = importlib.util.spec_from_file_location(name, root / rel)
        mod = importlib.util.module_from_spec(spec)
        spec.loader.exec_module(mod)
        return mod

    bench = _load("bench_kernels", "benchmarks/kernels.py")
    checker = _load("check_bench_schema", "tools/check_bench_schema.py")

    rec = bench.run(shapes=((8, 2, 32, 8),), iters=1, seed_cache=True)
    assert checker.validate(rec) == []
    kinds = {e["kernel"] for e in rec["sweep"]}
    assert kinds == {"dequant_bag_rowgrid", "dequant_bag", "bag_grad",
                     "unfused_bag_matmul", "bag_matmul"}
    for e in rec["sweep"]:
        assert e["measured_us"] <= e["analytic_us"] * (1 + 1e-6)
    # the seeded entries are served back by the resolvers
    assert autotune.lookup_cached("dequant_bag", "int8",
                                  8, 2, 32) is not None
    assert autotune.lookup_cached("bag_matmul", "int8", 8, 2, 32,
                                  extra="|h=8") is not None
