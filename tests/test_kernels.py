"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, interpreted."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

import repro.kernels
from repro.core import FQuantConfig, pack
from repro.core import packed_store as ps
from repro.core import qat_store as qs
from repro.kernels import should_interpret
from repro.kernels.cin.kernel import cin_layer_pallas
from repro.kernels.cin.ref import cin_layer_ref
from repro.kernels.dequant_bag.kernel import (
    dequant_bag_pallas,
    dequant_bag_pallas_rowgrid,
)
from repro.kernels.dequant_bag.ops import (
    packed_bag_lookup,
    packed_lookup_fused,
    pick_block_sizes,
)
from repro.kernels.dequant_bag.ref import dequant_bag_ref
from repro.kernels.rowwise_quant.kernel import quantize_rowwise_pallas
from repro.kernels.rowwise_quant.ref import quantize_rowwise_ref


@pytest.mark.parametrize("shape", [(8, 128), (300, 128), (256, 64),
                                   (1, 256), (1000, 32)])
@pytest.mark.parametrize("mode", ["narrow", "full"])
def test_rowwise_quant_rtn_sweep(shape, mode):
    x = jax.random.normal(jax.random.PRNGKey(0), shape) * 0.05
    q1, s1 = quantize_rowwise_pallas(x, mode=mode)
    q2, s2 = quantize_rowwise_ref(x, mode=mode)
    # values exactly on a .5 rounding boundary may land one level apart
    # between the fused kernel and the oracle (1-ulp scale difference);
    # allow <=1 level on <1% of entries, exact elsewhere.
    dq = np.abs(np.asarray(q1, np.int32) - np.asarray(q2, np.int32))
    assert dq.max() <= 1
    assert (dq != 0).mean() < 0.03
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-6)


@pytest.mark.parametrize("shape", [(64, 128), (129, 64)])
def test_rowwise_quant_stochastic_sweep(shape):
    x = jax.random.normal(jax.random.PRNGKey(1), shape) * 0.02
    noise = jax.random.uniform(jax.random.PRNGKey(2), shape)
    q1, _ = quantize_rowwise_pallas(x, noise)
    q2, _ = quantize_rowwise_ref(x, noise)
    np.testing.assert_array_equal(np.asarray(q1), np.asarray(q2))


@pytest.mark.parametrize("payload_dtype", [jnp.int8, jnp.bfloat16,
                                           jnp.float32])
@pytest.mark.parametrize("v,d,b,k", [(64, 128, 8, 5), (32, 64, 16, 1),
                                     (128, 256, 4, 9)])
def test_dequant_bag_sweep(payload_dtype, v, d, b, k):
    key = jax.random.PRNGKey(0)
    if payload_dtype == jnp.int8:
        payload = jax.random.randint(key, (v, d), -128, 127, jnp.int8)
    else:
        payload = (jax.random.normal(key, (v, d)) * 0.1
                   ).astype(payload_dtype)
    scales = jax.random.uniform(jax.random.PRNGKey(1), (v,)) * 0.01
    idx = jax.random.randint(jax.random.PRNGKey(2), (b, k), 0, v)
    w = jax.random.uniform(jax.random.PRNGKey(3), (b, k))
    out = dequant_bag_pallas(payload, scales, idx, w)
    ref = dequant_bag_ref(payload, scales, idx, w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-6)


def _bag_case(v, d, b, k, seed=0, payload_dtype=jnp.int8, zero_frac=0.3):
    key = jax.random.PRNGKey(seed)
    if payload_dtype == jnp.int8:
        payload = jax.random.randint(key, (v, d), -128, 127, jnp.int8)
    else:
        payload = (jax.random.normal(key, (v, d)) * 0.1
                   ).astype(payload_dtype)
    scales = jax.random.uniform(jax.random.PRNGKey(seed + 1), (v,)) * 0.01
    idx = jax.random.randint(jax.random.PRNGKey(seed + 2), (b, k), 0, v)
    w = jax.random.uniform(jax.random.PRNGKey(seed + 3), (b, k))
    w = w * (w > zero_frac)  # sprinkle zero-weight (padded) slots
    return payload, scales, idx, w


def test_dequant_bag_tiled_bit_identical_to_rowgrid():
    """The tiled (B_block, D_block) kernel accumulates each bag in the
    same k order as the pre-refactor (B, K)-grid kernel -> bit-equal."""
    for shape in [(64, 128, 8, 5), (32, 64, 16, 1), (128, 256, 7, 9),
                  (50, 24, 3, 4), (40, 48, 5, 3)]:
        for dt in (jnp.int8, jnp.bfloat16, jnp.float32):
            payload, scales, idx, w = _bag_case(*shape, payload_dtype=dt)
            tiled = dequant_bag_pallas(payload, scales, idx, w)
            rowgrid = dequant_bag_pallas_rowgrid(payload, scales, idx, w)
            np.testing.assert_array_equal(np.asarray(tiled),
                                          np.asarray(rowgrid))


def test_dequant_bag_block_size_invariance_bitwise():
    """Block geometry changes DMA batching, never accumulation order:
    any (block_b, block_d) choice gives bit-identical bags."""
    payload, scales, idx, w = _bag_case(80, 96, 11, 6)
    base = dequant_bag_pallas(payload, scales, idx, w,
                              block_b=1, block_d=96)
    for bb, bd in [(2, 48), (4, 96), (8, 32), (16, 96), (3, 16)]:
        out = dequant_bag_pallas(payload, scales, idx, w,
                                 block_b=bb, block_d=bd)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(base))


def test_dequant_bag_empty_bags():
    """All-zero-weight bags (fully padded requests) come back exactly
    zero — the kernel skips every DMA for them."""
    payload, scales, idx, _ = _bag_case(48, 32, 6, 4)
    w = jnp.zeros((6, 4), jnp.float32)
    out = dequant_bag_pallas(payload, scales, idx, w)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.zeros((6, 32), np.float32))
    # mixed: bags 1 and 4 empty, others live
    w = jax.random.uniform(jax.random.PRNGKey(9), (6, 4)) + 0.1
    w = w.at[1].set(0.0).at[4].set(0.0)
    out = dequant_bag_pallas(payload, scales, idx, w)
    ref = dequant_bag_ref(payload, scales, idx, w)
    np.testing.assert_array_equal(np.asarray(out)[[1, 4]],
                                  np.zeros((2, 32), np.float32))
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-6, atol=1e-7)


def test_dequant_bag_k1_bit_identical_to_ref():
    """K = 1 has no accumulation, so tiled == ref exactly — the property
    the fused serving lookup's bit-identity rests on."""
    for dt in (jnp.int8, jnp.bfloat16, jnp.float32):
        payload, scales, idx, w = _bag_case(64, 40, 13, 1,
                                            payload_dtype=dt)
        out = dequant_bag_pallas(payload, scales, idx, w)
        ref = dequant_bag_ref(payload, scales, idx, w)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_dequant_bag_d_not_multiple_of_block():
    """Explicit block_d that does not divide D (and one larger than D)
    exercises the column-padding correctness path."""
    payload, scales, idx, w = _bag_case(32, 20, 4, 3)
    ref = dequant_bag_pallas(payload, scales, idx, w,
                             block_b=2, block_d=20)
    for bd in (7, 13, 32):
        out = dequant_bag_pallas(payload, scales, idx, w,
                                 block_b=2, block_d=bd)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


@settings(max_examples=16, deadline=None)
@given(st.integers(1, 12), st.integers(1, 7), st.integers(1, 96),
       st.integers(0, 10_000))
def test_dequant_bag_tiled_property_vs_ref(b, k, d, seed):
    """Property: for random (B, K, D) and weights (with zeros), the
    tiled kernel under picked blocks matches the jnp oracle to fp32
    accumulation-order tolerance and the rowgrid kernel exactly."""
    v = 32
    payload, scales, idx, w = _bag_case(v, d, b, k, seed=seed % 97)
    out = dequant_bag_pallas(payload, scales, idx, w)
    ref = dequant_bag_ref(payload, scales, idx, w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-6)
    rowgrid = dequant_bag_pallas_rowgrid(payload, scales, idx, w)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(rowgrid))


def _working_set(bb, bd, k, itemsize):
    # mirrors ops._auto_block_b: fp32 out tile + landing ring +
    # gathered scale/weight blocks
    from repro.kernels.dequant_bag.ops import resolve_nbuf
    nbuf = resolve_nbuf(bb * k)
    return bb * bd * 4 + nbuf * bd * itemsize + 2 * bb * k * 4


def test_pick_block_sizes_properties():
    for b, k, d, itemsize in [(1, 1, 1, 1), (256, 8, 512, 1),
                              (1024, 64, 384, 2), (7, 3, 250, 4),
                              (64, 1, 2048, 4)]:
        bb, bd = pick_block_sizes(b, k, d, itemsize)
        assert 1 <= bb <= max(1, b)
        assert d % bd == 0, (d, bd)
        assert bd <= max(d, 1)
        # working set stays under the VMEM budget (or is minimal bb=1)
        assert bb == 1 or _working_set(bb, bd, k, itemsize) <= 2 << 20


def test_pick_block_sizes_awkward_dims():
    """Prime/odd D > 512 has no 128-aligned divisor; the picker must
    return a 128-aligned non-divisor (edge-padded in-kernel) instead of
    serializing the D axis with block_d=1."""
    for d in (521, 1013, 999, 2049):
        bb, bd = pick_block_sizes(64, 4, d, 1)
        assert bd % 128 == 0 and bd <= 512, (d, bd)
        assert bd > 1
    # small awkward dims keep the exact-divisor behaviour (no padding)
    for d in (250, 96, 7):
        _, bd = pick_block_sizes(64, 4, d, 1)
        assert d % bd == 0
    # and the non-divisor pick still runs correctly end to end
    payload, scales, idx, w = _bag_case(32, 521, 4, 3)
    out = dequant_bag_pallas(payload, scales, idx, w)
    ref = dequant_bag_ref(payload, scales, idx, w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-6)


def test_pick_block_sizes_env_override(monkeypatch):
    base = pick_block_sizes(64, 4, 128, 1)
    monkeypatch.setenv("REPRO_DEQUANT_BLOCK_B", "3")
    monkeypatch.setenv("REPRO_DEQUANT_BLOCK_D", "16")
    # env is read per call — overrides apply even after a cached pick
    assert pick_block_sizes(64, 4, 128, 1) == (3, 16)
    # overriding D alone re-sizes B against the new D (budget stays
    # consistent), instead of pairing it with the auto-D's B
    monkeypatch.delenv("REPRO_DEQUANT_BLOCK_B")
    monkeypatch.setenv("REPRO_DEQUANT_BLOCK_D", "1024")
    bb, bd = pick_block_sizes(1024, 64, 128, 1)
    assert bd == 1024
    assert bb == 1 or _working_set(bb, 1024, 64, 1) <= 2 << 20
    monkeypatch.delenv("REPRO_DEQUANT_BLOCK_D")
    assert pick_block_sizes(64, 4, 128, 1) == base


def test_resolve_block_sizes_call_arg_overrides():
    from repro.kernels.dequant_bag.ops import resolve_block_sizes
    # pinning D alone re-sizes B against the pinned value — the VMEM
    # working-set budget holds for call-arg overrides like env overrides
    bb, bd = resolve_block_sizes(1024, 64, 128, 1, block_d=1024)
    assert bd == 1024
    assert bb == 1 or _working_set(bb, 1024, 64, 1) <= 2 << 20
    bb2, bd2 = resolve_block_sizes(64, 4, 128, 1, block_b=5)
    assert (bb2, bd2) == (5, 128)
    for bad in ({"block_b": 0}, {"block_d": -1}):
        with pytest.raises(ValueError):
            resolve_block_sizes(8, 2, 16, 1, **bad)


def test_should_interpret_autodetect_and_overrides(monkeypatch):
    """CPU backend -> interpret by default; arg beats env beats
    detection."""
    repro.kernels._default_interpret.cache_clear()
    try:
        assert should_interpret() is True          # tests run on CPU
        assert should_interpret(False) is False    # explicit arg wins
        assert should_interpret(True) is True
        monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "0")
        repro.kernels._default_interpret.cache_clear()
        assert should_interpret() is False         # env forces compile
        assert should_interpret(True) is True      # arg still wins
        monkeypatch.setenv("REPRO_PALLAS_INTERPRET", "1")
        repro.kernels._default_interpret.cache_clear()
        assert should_interpret() is True
    finally:
        repro.kernels._default_interpret.cache_clear()


def test_packed_lookup_fused_bit_identical():
    """The fused per-tier K=1 path == packed_store.lookup, bit for bit,
    for any index shape."""
    cfg = FQuantConfig(stochastic=False)
    stt = qs.init(jax.random.PRNGKey(0), 96, 64, scale=0.05)
    pri = jnp.concatenate([jnp.zeros(32), jnp.full(32, 1e4),
                           jnp.full(32, 1e6)])
    stt = stt._replace(priority=pri)
    stt = stt._replace(table=qs.snap(stt.table,
                                     qs.current_tiers(stt, cfg), cfg))
    packed = pack(stt, cfg)
    for shape in [(17,), (6, 7), (2, 3, 4)]:
        idx = jax.random.randint(jax.random.PRNGKey(1), shape, 0, 96)
        fused = packed_lookup_fused(packed, idx, use_pallas=True)
        orac = ps.lookup(packed, idx)
        assert fused.shape == orac.shape
        np.testing.assert_array_equal(np.asarray(fused),
                                      np.asarray(orac))
    # use_pallas=False delegates to the oracle itself
    idx = jnp.arange(9)
    np.testing.assert_array_equal(
        np.asarray(packed_lookup_fused(packed, idx, use_pallas=False)),
        np.asarray(ps.lookup(packed, idx)))
    # packed_store.lookup_fused is the same entry point
    np.testing.assert_array_equal(
        np.asarray(ps.lookup_fused(packed, idx, use_pallas=True)),
        np.asarray(ps.lookup(packed, idx)))


def test_packed_bag_lookup_weighted():
    cfg = FQuantConfig(stochastic=False)
    stt = qs.init(jax.random.PRNGKey(2), 96, 32, scale=0.05)
    pri = jnp.concatenate([jnp.zeros(32), jnp.full(32, 1e4),
                           jnp.full(32, 1e6)])
    stt = stt._replace(priority=pri)
    stt = stt._replace(table=qs.snap(stt.table,
                                     qs.current_tiers(stt, cfg), cfg))
    packed = pack(stt, cfg)
    rng = np.random.default_rng(4)
    idx = jnp.asarray(rng.integers(0, 96, (5, 6)).astype(np.int32))
    w = jnp.asarray(rng.uniform(0, 1, (5, 6)).astype(np.float32))
    out = packed_bag_lookup(packed, idx, weights=w, use_pallas=True)
    rows = np.asarray(ps.lookup(packed, idx)) * np.asarray(w)[..., None]
    np.testing.assert_allclose(np.asarray(out), rows.sum(axis=1),
                               rtol=1e-5, atol=1e-6)


def test_packed_bag_lookup_vs_jnp_path():
    from repro.core.packed_store import bag_lookup as jnp_bag
    cfg = FQuantConfig(stochastic=False)
    st = qs.init(jax.random.PRNGKey(0), 96, 64, scale=0.05)
    pri = jnp.concatenate([jnp.zeros(32), jnp.full(32, 1e4),
                           jnp.full(32, 1e6)])
    st = st._replace(priority=pri)
    st = st._replace(table=qs.snap(st.table, qs.current_tiers(st, cfg),
                                   cfg))
    packed = pack(st, cfg)
    idx = jax.random.randint(jax.random.PRNGKey(1), (6, 4), 0, 96)
    out = packed_bag_lookup(packed, idx)
    seg = jnp.repeat(jnp.arange(6), 4)
    ref = jnp_bag(packed, idx.reshape(-1), seg, 6)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("b,h,m,d,o", [(17, 12, 10, 8, 24),
                                       (64, 39, 39, 10, 200),
                                       (3, 5, 7, 4, 2)])
def test_cin_sweep(b, h, m, d, o):
    key = jax.random.PRNGKey(0)
    w = jax.random.normal(key, (o, h, m)) * 0.1
    xk = jax.random.normal(jax.random.PRNGKey(1), (b, h, d))
    x0 = jax.random.normal(jax.random.PRNGKey(2), (b, m, d))
    out = cin_layer_pallas(w, xk, x0)
    ref = cin_layer_ref(w, xk, x0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_cin_block_invariance():
    """Different block shapes give identical results."""
    key = jax.random.PRNGKey(3)
    w = jax.random.normal(key, (32, 8, 8)) * 0.1
    xk = jax.random.normal(jax.random.PRNGKey(4), (40, 8, 16))
    x0 = jax.random.normal(jax.random.PRNGKey(5), (40, 8, 16))
    a = cin_layer_pallas(w, xk, x0, block_b=8, block_o=8)
    b_ = cin_layer_pallas(w, xk, x0, block_b=64, block_o=32)
    # block shape changes the fp32 accumulation order -> allclose not equal
    np.testing.assert_allclose(np.asarray(a), np.asarray(b_), rtol=1e-4,
                               atol=1e-5)
