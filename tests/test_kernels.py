"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, interpret=True."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import FQuantConfig, pack
from repro.core import qat_store as qs
from repro.kernels.cin.kernel import cin_layer_pallas
from repro.kernels.cin.ref import cin_layer_ref
from repro.kernels.dequant_bag.kernel import dequant_bag_pallas
from repro.kernels.dequant_bag.ops import packed_bag_lookup
from repro.kernels.dequant_bag.ref import dequant_bag_ref
from repro.kernels.rowwise_quant.kernel import quantize_rowwise_pallas
from repro.kernels.rowwise_quant.ref import quantize_rowwise_ref


@pytest.mark.parametrize("shape", [(8, 128), (300, 128), (256, 64),
                                   (1, 256), (1000, 32)])
@pytest.mark.parametrize("mode", ["narrow", "full"])
def test_rowwise_quant_rtn_sweep(shape, mode):
    x = jax.random.normal(jax.random.PRNGKey(0), shape) * 0.05
    q1, s1 = quantize_rowwise_pallas(x, mode=mode)
    q2, s2 = quantize_rowwise_ref(x, mode=mode)
    # values exactly on a .5 rounding boundary may land one level apart
    # between the fused kernel and the oracle (1-ulp scale difference);
    # allow <=1 level on <1% of entries, exact elsewhere.
    dq = np.abs(np.asarray(q1, np.int32) - np.asarray(q2, np.int32))
    assert dq.max() <= 1
    assert (dq != 0).mean() < 0.03
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-6)


@pytest.mark.parametrize("shape", [(64, 128), (129, 64)])
def test_rowwise_quant_stochastic_sweep(shape):
    x = jax.random.normal(jax.random.PRNGKey(1), shape) * 0.02
    noise = jax.random.uniform(jax.random.PRNGKey(2), shape)
    q1, _ = quantize_rowwise_pallas(x, noise)
    q2, _ = quantize_rowwise_ref(x, noise)
    np.testing.assert_array_equal(np.asarray(q1), np.asarray(q2))


@pytest.mark.parametrize("payload_dtype", [jnp.int8, jnp.bfloat16,
                                           jnp.float32])
@pytest.mark.parametrize("v,d,b,k", [(64, 128, 8, 5), (32, 64, 16, 1),
                                     (128, 256, 4, 9)])
def test_dequant_bag_sweep(payload_dtype, v, d, b, k):
    key = jax.random.PRNGKey(0)
    if payload_dtype == jnp.int8:
        payload = jax.random.randint(key, (v, d), -128, 127, jnp.int8)
    else:
        payload = (jax.random.normal(key, (v, d)) * 0.1
                   ).astype(payload_dtype)
    scales = jax.random.uniform(jax.random.PRNGKey(1), (v,)) * 0.01
    idx = jax.random.randint(jax.random.PRNGKey(2), (b, k), 0, v)
    w = jax.random.uniform(jax.random.PRNGKey(3), (b, k))
    out = dequant_bag_pallas(payload, scales, idx, w)
    ref = dequant_bag_ref(payload, scales, idx, w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-6)


def test_packed_bag_lookup_vs_jnp_path():
    from repro.core.packed_store import bag_lookup as jnp_bag
    cfg = FQuantConfig(stochastic=False)
    st = qs.init(jax.random.PRNGKey(0), 96, 64, scale=0.05)
    pri = jnp.concatenate([jnp.zeros(32), jnp.full(32, 1e4),
                           jnp.full(32, 1e6)])
    st = st._replace(priority=pri)
    st = st._replace(table=qs.snap(st.table, qs.current_tiers(st, cfg),
                                   cfg))
    packed = pack(st, cfg)
    idx = jax.random.randint(jax.random.PRNGKey(1), (6, 4), 0, 96)
    out = packed_bag_lookup(packed, idx)
    seg = jnp.repeat(jnp.arange(6), 4)
    ref = jnp_bag(packed, idx.reshape(-1), seg, 6)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("b,h,m,d,o", [(17, 12, 10, 8, 24),
                                       (64, 39, 39, 10, 200),
                                       (3, 5, 7, 4, 2)])
def test_cin_sweep(b, h, m, d, o):
    key = jax.random.PRNGKey(0)
    w = jax.random.normal(key, (o, h, m)) * 0.1
    xk = jax.random.normal(jax.random.PRNGKey(1), (b, h, d))
    x0 = jax.random.normal(jax.random.PRNGKey(2), (b, m, d))
    out = cin_layer_pallas(w, xk, x0)
    ref = cin_layer_ref(w, xk, x0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_cin_block_invariance():
    """Different block shapes give identical results."""
    key = jax.random.PRNGKey(3)
    w = jax.random.normal(key, (32, 8, 8)) * 0.1
    xk = jax.random.normal(jax.random.PRNGKey(4), (40, 8, 16))
    x0 = jax.random.normal(jax.random.PRNGKey(5), (40, 8, 16))
    a = cin_layer_pallas(w, xk, x0, block_b=8, block_o=8)
    b_ = cin_layer_pallas(w, xk, x0, block_b=64, block_o=32)
    # block shape changes the fp32 accumulation order -> allclose not equal
    np.testing.assert_allclose(np.asarray(a), np.asarray(b_), rtol=1e-4,
                               atol=1e-5)
