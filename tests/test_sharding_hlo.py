"""Sharding rules, HLO analyzer, split-KV decode collective."""

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.dist import sharding as sh
from repro.launch.hlo_analysis import analyze


def test_lm_param_rules():
    params = {
        "embed": jax.ShapeDtypeStruct((512, 64), jnp.float32),
        "layers": {"attn": {"wq": {"w": jax.ShapeDtypeStruct(
            (8, 64, 64), jnp.float32)}}},
        "final_norm": {"g": jax.ShapeDtypeStruct((64,), jnp.float32)},
    }
    specs = sh.param_specs(params, "lm")
    assert specs["embed"] == P("model", "data")
    # stacked layer param: leading L axis unsharded
    assert specs["layers"]["attn"]["wq"]["w"] == P(None, "data", "model")
    assert specs["final_norm"]["g"] == P()


def test_recsys_rules_row_shard_tables_only():
    params = {
        "embed_table": jax.ShapeDtypeStruct((1024, 16), jnp.float32),
        "wide_table": jax.ShapeDtypeStruct((1024, 1), jnp.float32),
        "net": {"deep": {"l0": {"w": jax.ShapeDtypeStruct(
            (128, 64), jnp.float32)}}},
    }
    specs = sh.param_specs(params, "recsys")
    assert specs["embed_table"] == P("model", None)
    assert specs["wide_table"] == P("model", None)
    assert specs["net"]["deep"]["l0"]["w"] == P()


def test_ep_rules_shard_experts():
    params = {"layers": {"moe": {
        "gate": jax.ShapeDtypeStruct((8, 64, 64, 32), jnp.float32)}}}
    specs = sh.param_specs(params, "lm_ep")
    assert specs["layers"]["moe"]["gate"] == P(None, "model", "data", None)


def test_zero1_specs_add_data_axis():
    pspec = {"w": P("model", None)}
    params = {"w": jax.ShapeDtypeStruct((64, 32), jnp.float32)}
    z = sh.zero1_specs(pspec, params, data_size=16)
    assert z["w"] == P("model", "data")


def test_validate_divisibility_flags_bad_dims():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    params = {"w": jax.ShapeDtypeStruct((7, 3), jnp.float32)}
    # trivial 1x1 mesh: everything divides
    assert sh.validate_divisibility(params, {"w": P("data", None)},
                                    mesh) == []


# ------------------------------------------------------------ HLO analyzer

def test_analyzer_counts_scan_trip_multipliers():
    n, L = 64, 7

    def f(x):
        def body(c, _):
            return c @ jnp.eye(n), None
        return jax.lax.scan(body, x, None, length=L)[0]

    hlo = jax.jit(f).lower(
        jax.ShapeDtypeStruct((n, n), jnp.float32)).compile().as_text()
    stats = analyze(hlo)
    assert stats.flops == pytest.approx(L * 2 * n ** 3, rel=0.01)


def test_analyzer_nested_scans_multiply():
    n, L1, L2 = 32, 3, 5

    def f(x):
        def inner(c, _):
            return c @ jnp.eye(n), None

        def outer(c, _):
            c2, _ = jax.lax.scan(inner, c, None, length=L2)
            return c2, None
        return jax.lax.scan(outer, x, None, length=L1)[0]

    hlo = jax.jit(f).lower(
        jax.ShapeDtypeStruct((n, n), jnp.float32)).compile().as_text()
    stats = analyze(hlo)
    assert stats.flops == pytest.approx(L1 * L2 * 2 * n ** 3, rel=0.01)


def test_analyzer_plain_dot():
    def f(a, b):
        return a @ b

    s = jax.ShapeDtypeStruct((128, 256), jnp.float32)
    t = jax.ShapeDtypeStruct((256, 64), jnp.float32)
    hlo = jax.jit(f).lower(s, t).compile().as_text()
    stats = analyze(hlo)
    assert stats.flops == pytest.approx(2 * 128 * 256 * 64, rel=0.01)
    assert stats.collective_total() == 0


# ----------------------------------------------- split-KV decode collective

def test_split_kv_decode_matches_full_softmax():
    """Run the shard_map split-KV decode on a 4-device host mesh in a
    subprocess (device count must be set before jax init)."""
    import subprocess
    import sys
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from repro.dist.collectives import split_kv_decode_attention
mesh = jax.make_mesh((4,), ("model",))
b, s, h, d = 2, 32, 4, 16
q = jax.random.normal(jax.random.PRNGKey(0), (b, h, d))
k = jax.random.normal(jax.random.PRNGKey(1), (b, s, h, d))
v = jax.random.normal(jax.random.PRNGKey(2), (b, s, h, d))
cache_len = jnp.asarray(19)
scale = d ** -0.5
out = split_kv_decode_attention(mesh, q, k, v, cache_len, scale)
# reference: full softmax over valid positions
sc = jnp.einsum("bhd,bkhd->bhk", q, k) * scale
mask = (jnp.arange(s) <= cache_len)[None, None, :]
sc = jnp.where(mask, sc, -1e30)
p = jax.nn.softmax(sc, axis=-1)
ref = jnp.einsum("bhk,bkhd->bhd", p, v)
np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                           rtol=2e-5, atol=2e-5)
print("SPLIT_KV_OK")
"""
    env = dict(**__import__("os").environ)
    env["PYTHONPATH"] = "src"
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env,
                       cwd=__import__("os").path.join(
                           __import__("os").path.dirname(__file__), ".."))
    assert "SPLIT_KV_OK" in r.stdout, r.stderr[-2000:]
