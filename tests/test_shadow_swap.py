"""Interleaving stress harness for shadow-store re-tiering.

The shadow swap's contract (src/repro/serve/shadow.py): however serve
steps, priority folds, chunked shadow builds, staging and swaps
interleave, every lookup is bit-identical to a **lockstep synchronous
oracle** — a full ``pack`` at the fold state of the LAST swap's
snapshot.  A deterministic scheduler executes hypothesis-generated op
schedules against an ``OnlineServer`` and checks that oracle after
every single op, plus the per-chunk-boundary invariant
(``ShadowRepack.materialize() == repack_delta(live, snapshot, cfg,
movers[:pos])``) at every chunk.

Named schedules cover the corners: swap-during-drift (the swap lands
the SNAPSHOT fold state, not the drifted live one), double-swap,
crash-before-swap (shadow discarded, live store untouched — including
the hier cold generation's unpublished tmp dir).  The same harness
runs at mesh=1 in-process and mesh=4 in a subprocess (the XLA host
device count must be fixed before jax initialises).
"""

import glob
import os
import subprocess
import sys
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import FQuantConfig, pack
from repro.core import packed_store as ps
from repro.core import qat_store as qs
from repro.core.tiers import TierConfig, tier_crossings
from repro.serve import OnlineConfig, OnlineServer
from repro.store.hier import hier_lookup

V, D = 160, 24
CFG = FQuantConfig(tiers=TierConfig(t8=5.0, t16=50.0), stochastic=False)

# op weights for generated schedules: mostly traffic, with enough
# begin/chunk/tick to keep a build in flight and the rare drain/discard
OPS = ("serve", "serve", "serve", "fold", "fold", "begin", "chunk",
       "chunk", "tick", "drain", "discard")


def _store(seed=0, scale_pri=20.0):
    rng = np.random.default_rng(seed)
    st_ = qs.init(jax.random.PRNGKey(seed), V, D, scale=0.05)
    pri = jnp.asarray((rng.pareto(1.2, V) * scale_pri)
                      .astype(np.float32))
    st_ = st_._replace(priority=pri)
    return st_._replace(table=qs.snap(
        st_.table, qs.current_tiers(st_, CFG), CFG))


def _flat_server(seed=0, mesh=None, retier_every=0):
    return OnlineServer(
        _store(seed), CFG,
        OnlineConfig(cache_rows=24, retier_every=retier_every,
                     retier_async=True, shadow_rows_per_step=16,
                     verify_swap=True),
        mesh=mesh)


def _mirror(server):
    """The synchronous oracle the live store must match right now."""
    return np.asarray(ps.unpack(server.host_packed))


def run_flat_schedule(server, ops, rng):
    """Execute one op schedule, asserting the lockstep oracle after
    every op.  ``mirror`` is the unpacked synchronous pack at the last
    swap's snapshot fold state; a swap may land inside ANY op (the
    staging thread finishing is scheduler-invisible), so the swap
    counter is re-checked after each one."""
    mirror = _mirror(server)
    np.testing.assert_array_equal(
        mirror, np.asarray(ps.unpack(pack(server.store, CFG))))
    last_snap = None
    swaps = 0
    for op in ops:
        pre_swaps = server.stats.swaps
        if op == "serve":
            idx = rng.integers(0, V, (8,)).astype(np.int32)
            rows = np.asarray(server.lookup(jnp.asarray(idx)))
            np.testing.assert_array_equal(rows, mirror[idx])
        elif op == "fold":
            idx = rng.integers(0, V, (16,)).astype(np.int32)
            server.observe(jnp.asarray(idx), count=4)
        elif op == "begin":
            server.begin_retier()
        elif op == "chunk":
            sh = server.shadow
            if sh is not None and not sh.staged:
                sh.step(int(rng.integers(1, 48)))
                got = np.asarray(ps.unpack(sh.materialize()))
                ref = np.asarray(ps.unpack(ps.repack_delta(
                    server.host_packed, sh.snapshot, CFG,
                    sh.movers[:sh.pos])))
                np.testing.assert_array_equal(got, ref)
        elif op == "tick":
            server._shadow_tick(1)
        elif op == "drain":
            server.drain_shadow()
        elif op == "discard":
            server.discard_shadow()
            # crash-before-swap: live store untouched
            np.testing.assert_array_equal(_mirror(server), mirror)
        if server.stats.swaps > pre_swaps:
            swaps += server.stats.swaps - pre_swaps
            mirror = np.asarray(ps.unpack(pack(last_snap, CFG)))
        np.testing.assert_array_equal(_mirror(server), mirror)
        if server.shadow is not None:
            last_snap = server.shadow.snapshot
    pre_swaps = server.stats.swaps
    server.drain_shadow()           # joins the staging thread too
    if server.stats.swaps > pre_swaps:
        swaps += server.stats.swaps - pre_swaps
        mirror = np.asarray(ps.unpack(pack(last_snap, CFG)))
    np.testing.assert_array_equal(_mirror(server), mirror)
    return swaps


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=10, deadline=None, derandomize=True)
def test_flat_schedules_bit_identical(seed):
    rng = np.random.default_rng(seed)
    server = _flat_server(seed=seed % 5)
    ops = [OPS[i] for i in rng.integers(0, len(OPS), 40)]
    run_flat_schedule(server, ops, rng)


def test_auto_mode_swaps_under_traffic():
    """retier_every-triggered builds: the server opens, chunks and
    swaps shadows on its own while every lookup stays on the oracle."""
    rng = np.random.default_rng(3)
    server = _flat_server(seed=3, retier_every=2)
    swaps = run_flat_schedule(server, ["serve"] * 60, rng)
    assert server.stats.shadow_builds >= 1
    assert swaps >= 1
    assert server.stats.rows_moved > 0


def test_swap_during_drift_lands_snapshot_state():
    """Priorities folded AFTER the snapshot must NOT leak into the
    swapped store: the swap equals pack() at the snapshot, and only the
    NEXT build picks the drift up."""
    rng = np.random.default_rng(11)
    server = _flat_server(seed=1)
    for _ in range(6):      # drift until some rows cross tiers
        server.observe(jnp.asarray(rng.integers(0, V, (64,))
                                   .astype(np.int32)), count=16)
    assert server.begin_retier()
    snap = server.shadow.snapshot
    # keep folding while the build is chunked — swap-during-drift
    while not server.shadow.staged:
        server.observe(jnp.asarray(rng.integers(0, V, (64,))
                                   .astype(np.int32)), count=16)
        if server.shadow is None:   # staged + swapped under traffic
            break
        server.shadow.step(16)
    drifted = server.store
    server.drain_shadow()
    assert server.stats.swaps == 1
    np.testing.assert_array_equal(
        np.asarray(ps.unpack(server.host_packed)),
        np.asarray(ps.unpack(pack(snap, CFG))))
    crossed, _ = tier_crossings(ps.packed_tiers(server.host_packed),
                                qs.current_tiers(drifted, CFG))
    if crossed.size:    # drift did cross tiers: live != pack(drifted)
        assert not np.array_equal(
            np.asarray(ps.unpack(server.host_packed)),
            np.asarray(ps.unpack(pack(drifted, CFG))))
    # the next build converges onto the drifted state
    server.begin_retier()
    final = server.shadow.snapshot if server.shadow is not None \
        else server.store
    server.drain_shadow()
    np.testing.assert_array_equal(
        np.asarray(ps.unpack(server.host_packed)),
        np.asarray(ps.unpack(pack(final, CFG))))


def test_double_swap_and_crash_before_swap():
    rng = np.random.default_rng(23)
    server = _flat_server(seed=2)

    def drift():
        for _ in range(4):
            server.observe(jnp.asarray(rng.integers(0, V, (64,))
                                       .astype(np.int32)), count=16)

    # crash-before-swap: partial build discarded, live untouched
    before = _mirror(server)
    drift()
    if server.begin_retier():
        server.shadow.step(8)
        server.discard_shadow()
    np.testing.assert_array_equal(_mirror(server), before)
    assert server.stats.swaps == 0

    # double-swap: two full cycles, each bit-identical at its snapshot
    for _ in range(2):
        drift()
        if server.begin_retier():
            snap = server.shadow.snapshot
            server.drain_shadow()
            np.testing.assert_array_equal(
                np.asarray(ps.unpack(server.host_packed)),
                np.asarray(ps.unpack(pack(snap, CFG))))
    # a begin with zero movers is the synchronous no-move path
    n_retier = server.stats.retiers
    assert not server.begin_retier() or server.shadow is not None
    server.drain_shadow()
    assert server.stats.retiers >= n_retier


def test_chunk_boundary_invariant_every_row():
    """Budget=1 stepping: the materialized shadow equals the partial
    synchronous repack at EVERY mover-row boundary."""
    rng = np.random.default_rng(5)
    server = _flat_server(seed=4)
    for _ in range(6):
        server.observe(jnp.asarray(rng.integers(0, V, (64,))
                                   .astype(np.int32)), count=16)
    assert server.begin_retier()
    sh = server.shadow
    assert sh.moved > 1
    while not sh.step(1):
        got = np.asarray(ps.unpack(sh.materialize()))
        ref = np.asarray(ps.unpack(ps.repack_delta(
            server.host_packed, sh.snapshot, CFG, sh.movers[:sh.pos])))
        np.testing.assert_array_equal(got, ref)
    sh.verify()
    server.drain_shadow()


@given(st.integers(0, 2**31 - 1), st.integers(1, 6))
@settings(max_examples=12, deadline=None, derandomize=True)
def test_repack_delta_chunk_composition(seed, nchunks):
    """N chunked deltas over any partition, applied in any order,
    compose to exactly one full pack at the final fold state."""
    rng = np.random.default_rng(seed)
    st_ = _store(seed=seed % 5)
    packed = pack(st_, CFG)
    st2 = st_._replace(priority=jnp.asarray(
        np.asarray(st_.priority)
        * rng.uniform(0.05, 20.0, V).astype(np.float32)))
    changed, _ = tier_crossings(ps.packed_tiers(packed),
                                qs.current_tiers(st2, CFG))
    acc = packed
    for part in np.array_split(rng.permutation(changed),
                               min(nchunks, max(changed.size, 1))):
        acc = ps.repack_delta(acc, st2, CFG, part)
    full = pack(st2, CFG)
    np.testing.assert_array_equal(np.asarray(ps.unpack(acc)),
                                  np.asarray(ps.unpack(full)))
    np.testing.assert_array_equal(
        np.bincount(ps.packed_tiers(acc), minlength=3),
        np.bincount(ps.packed_tiers(full), minlength=3))
    assert acc.nbytes() == full.nbytes()


def _hier_server(store_dir, seed=0):
    from repro.store import HierConfig
    st_ = _store(seed)
    full = pack(st_, CFG).nbytes()
    budget = max(1, int(full * 0.3))
    return OnlineServer(
        st_, CFG,
        OnlineConfig(cache_rows=8, retier_every=0, retier_async=True,
                     shadow_rows_per_step=16, verify_swap=True),
        hier=HierConfig(hbm_budget_bytes=budget,
                        host_budget_bytes=budget,
                        rows_per_shard=16, store_dir=store_dir))


def _hier_mirror(server):
    return np.asarray(hier_lookup(server.hier, np.arange(V)))


def run_hier_schedule(server, ops, rng, store_dir):
    """Hier twin of the flat scheduler: the oracle is the level-resolved
    lookup of every row, which must equal pack() at the last swap's
    snapshot; discard must additionally leave no unpublished cold tmp
    generation behind."""
    mirror = _hier_mirror(server)
    np.testing.assert_array_equal(
        mirror, np.asarray(ps.unpack(pack(server.store, CFG))))
    last_snap = None
    for op in ops:
        pre_swaps = server.stats.swaps
        if op == "serve":
            idx = rng.integers(0, V, (6, 4)).astype(np.int32)
            rows = np.asarray(server.lookup(jnp.asarray(idx)))
            np.testing.assert_array_equal(rows, mirror[idx])
        elif op == "fold":
            idx = rng.integers(0, V, (16,)).astype(np.int32)
            server.observe(jnp.asarray(idx), count=4)
        elif op == "begin":
            server.begin_retier()
        elif op == "chunk":
            sh = server.shadow
            if sh is not None and not sh.staged:
                before = sh.done_rows
                sh.step(int(rng.integers(1, 48)))
                assert sh.done_rows >= before
        elif op == "tick":
            server._shadow_tick(1)
        elif op == "drain":
            server.drain_shadow()
        elif op == "discard":
            server.discard_shadow()
            np.testing.assert_array_equal(_hier_mirror(server), mirror)
            assert not glob.glob(os.path.join(store_dir, "**",
                                              ".tmp_hier_*"),
                                 recursive=True)
        if server.stats.swaps > pre_swaps:
            mirror = np.asarray(ps.unpack(pack(last_snap, CFG)))
        np.testing.assert_array_equal(_hier_mirror(server), mirror)
        if server.shadow is not None:
            last_snap = server.shadow.snapshot
    pre_swaps = server.stats.swaps
    server.drain_shadow()
    if server.stats.swaps > pre_swaps:
        mirror = np.asarray(ps.unpack(pack(last_snap, CFG)))
    np.testing.assert_array_equal(_hier_mirror(server), mirror)


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=6, deadline=None, derandomize=True)
def test_hier_schedules_bit_identical(seed):
    rng = np.random.default_rng(seed)
    store_dir = tempfile.mkdtemp(prefix="shadow_swap_hier_")
    server = _hier_server(store_dir, seed=seed % 5)
    ops = [OPS[i] for i in rng.integers(0, len(OPS), 30)]
    run_hier_schedule(server, ops, rng, store_dir)


def test_hier_cold_rewrite_and_crash_before_swap():
    """An outright priority reversal forces the cold set to change: the
    shadow stages a NEW cold generation shard-by-shard in a hidden tmp
    dir; discard before the swap removes it and the live generation
    (open mmaps included) keeps serving bit-identically."""
    store_dir = tempfile.mkdtemp(prefix="shadow_swap_cold_")
    server = _hier_server(store_dir, seed=6)
    before = _hier_mirror(server)
    pri = np.asarray(server.store.priority)
    server.store = server.store._replace(
        priority=jnp.asarray(pri[::-1].copy()))
    assert server.begin_retier()
    sh = server.shadow
    assert sh._cold_needed
    snap = sh.snapshot
    while not sh.step(32):      # builds + one cold shard per call
        np.testing.assert_array_equal(_hier_mirror(server), before)
    # crash-before-swap: tmp generation discarded, live untouched
    server.discard_shadow()
    assert not glob.glob(os.path.join(store_dir, "**", ".tmp_hier_*"),
                         recursive=True)
    np.testing.assert_array_equal(_hier_mirror(server), before)
    # the rebuilt shadow swaps onto the snapshot fold state
    server.store = snap
    assert server.begin_retier()
    server.drain_shadow()
    assert server.stats.swaps == 1
    np.testing.assert_array_equal(
        _hier_mirror(server),
        np.asarray(ps.unpack(pack(snap, CFG))))


def test_flat_schedule_sharded_4way():
    """The generated-schedule harness under a 4-way row-sharded mesh:
    same oracle, device placement through shard_packed/place_packed."""
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys
sys.path.insert(0, "tests")
try:
    import hypothesis  # noqa: F401
except ImportError:
    from _hypothesis_stub import install
    install()
import numpy as np, jax
from test_shadow_swap import OPS, _flat_server, run_flat_schedule

mesh = jax.make_mesh((4,), ("model",))
rng = np.random.default_rng(7)
server = _flat_server(seed=1, mesh=mesh)
ops = [OPS[i] for i in rng.integers(0, len(OPS), 30)]
run_flat_schedule(server, ops, rng)

# and an auto-mode pass that must actually swap under the mesh
rng = np.random.default_rng(8)
server = _flat_server(seed=2, mesh=mesh, retier_every=2)
swaps = run_flat_schedule(server, ["serve"] * 50, rng)
assert swaps >= 1, "no swap landed under the 4-way mesh"
print("SHADOW_SWAP_MESH4_OK")
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in ("src", env.get("PYTHONPATH", "")) if p)
    env.pop("JAX_PLATFORMS", None)
    env["JAX_PLATFORMS"] = "cpu"
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env,
                       cwd=os.path.join(os.path.dirname(__file__), ".."))
    assert "SHADOW_SWAP_MESH4_OK" in r.stdout, r.stderr[-2000:]
