"""Online serving oracle tests: repack_delta == full pack (bit-exact,
single-device and row-sharded), hot-cache bit-identity, OnlineServer
end-to-end."""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import FQuantConfig, pack
from repro.core import packed_store as ps
from repro.core import qat_store as qs
from repro.core.priority import serve_update
from repro.core.tiers import TierConfig, tier_crossings
from repro.serve import (
    OnlineConfig,
    OnlineServer,
    build_cache,
    cached_lookup,
    drifting_zipf_batch,
    empty_cache,
)

V, D = 160, 24
CFG = FQuantConfig(tiers=TierConfig(t8=5.0, t16=50.0), stochastic=False)


def _store(seed=0, scale_pri=20.0):
    rng = np.random.default_rng(seed)
    st = qs.init(jax.random.PRNGKey(seed), V, D, scale=0.05)
    pri = jnp.asarray((rng.pareto(1.2, V) * scale_pri).astype(np.float32))
    st = st._replace(priority=pri)
    return st._replace(table=qs.snap(
        st.table, qs.current_tiers(st, CFG), CFG))


def _perturb(st, rng):
    f = rng.uniform(0.05, 20.0, V).astype(np.float32)
    return st._replace(priority=jnp.asarray(np.asarray(st.priority) * f))


def test_repack_delta_matches_full_pack_bitwise():
    """Iterated delta repacks after random priority perturbations stay
    bit-identical to a fresh full pack (unpack round-trip), with exact
    candidate sets from tier_crossings."""
    rng = np.random.default_rng(7)
    st = _store()
    packed = pack(st, CFG)
    for _ in range(6):
        st = _perturb(st, rng)
        changed, hist = tier_crossings(
            ps.packed_tiers(packed), qs.current_tiers(st, CFG))
        assert hist.sum() == changed.size
        packed = ps.repack_delta(packed, st, CFG, changed)
        full = pack(st, CFG)
        np.testing.assert_array_equal(np.asarray(ps.unpack(packed)),
                                      np.asarray(ps.unpack(full)))
        # tier populations (hence memory accounting) match too
        np.testing.assert_array_equal(
            np.bincount(ps.packed_tiers(packed), minlength=3),
            np.bincount(ps.packed_tiers(full), minlength=3))
        assert packed.nbytes() == full.nbytes()


def test_repack_delta_candidate_superset_and_noop():
    rng = np.random.default_rng(3)
    st = _store(seed=1)
    packed = pack(st, CFG)
    # no priority change -> no-op (same object)
    assert ps.repack_delta(packed, st, CFG, np.arange(V)) is packed
    # a full-vocab candidate set degrades to the exact mover set
    st2 = _perturb(st, rng)
    a = ps.repack_delta(packed, st2, CFG, np.arange(V))
    changed, _ = tier_crossings(ps.packed_tiers(packed),
                                qs.current_tiers(st2, CFG))
    b = ps.repack_delta(packed, st2, CFG, changed)
    np.testing.assert_array_equal(np.asarray(ps.unpack(a)),
                                  np.asarray(ps.unpack(b)))


def test_repack_delta_tier_emptied_and_refilled():
    """Forcing every row through one tier exercises the 1-row
    placeholder convention for emptied payload arrays."""
    st = _store(seed=2)
    packed = pack(st, CFG)
    for pri in (np.zeros(V), np.full(V, 1e3), np.zeros(V)):
        st = st._replace(priority=jnp.asarray(pri, jnp.float32))
        packed = ps.repack_delta(packed, st, CFG, np.arange(V))
        np.testing.assert_array_equal(
            np.asarray(ps.unpack(packed)),
            np.asarray(ps.unpack(pack(st, CFG))))


def test_pack_and_repack_scale_dtypes_stay_fp32():
    """Regression: scale columns must stay fp32 through pack AND the
    repack_delta host round-trip (numpy promotes to float64 on contact
    with python floats; a float64 scale column doubles serving scale
    bytes and breaks delta-vs-full-pack bit-identity)."""
    rng = np.random.default_rng(13)
    st = _store(seed=11)
    packed = pack(st, CFG)

    def check(p, where):
        assert p.scale8.dtype == jnp.float32, where
        assert p.scale16.dtype == jnp.float32, where
        assert p.payload8.dtype == jnp.int8, where
        assert p.payload16.dtype == jnp.bfloat16, where
        assert p.payload32.dtype == jnp.float32, where

    check(packed, "pack")
    for i in range(3):
        st = _perturb(st, rng)
        packed = ps.repack_delta(packed, st, CFG, np.arange(V))
        check(packed, f"repack_delta[{i}]")
    # _quantize_tier normalises even float64 host rows
    from repro.core.packed_store import _quantize_tier
    from repro.core.tiers import Tier
    rows64 = rng.standard_normal((4, D))            # float64
    for tier in (Tier.INT8, Tier.HALF):
        _, s = _quantize_tier(rows64, tier, CFG)
        assert s.dtype == np.float32, tier


def test_hot_cache_bit_identical_and_hit_accounting():
    st = _store(seed=3)
    packed = pack(st, CFG)
    cache = build_cache(packed, st.priority, 32)
    assert cache.capacity == 32
    rng = np.random.default_rng(11)
    idx = jnp.asarray(rng.integers(0, V, (16, 6)).astype(np.int32))
    out, hits = cached_lookup(packed, cache, idx)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(ps.lookup(packed, idx)))
    in_cache = np.isin(np.asarray(idx), np.asarray(cache.ids))
    assert int(hits) == int(in_cache.sum())
    # all-resident batch: every lookup hits
    hot = jnp.asarray(np.asarray(cache.ids)[:8])
    _, hits = cached_lookup(packed, cache, hot)
    assert int(hits) == 8


def test_empty_and_oversized_cache():
    st = _store(seed=4)
    packed = pack(st, CFG)
    cache = empty_cache(V, D)
    idx = jnp.arange(10)
    out, hits = cached_lookup(packed, cache, idx)
    assert int(hits) == 0
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(ps.lookup(packed, idx)))
    big = build_cache(packed, st.priority, V + 100)  # clamped to vocab
    assert big.capacity == V
    out, hits = cached_lookup(packed, big, idx)
    assert int(hits) == 10
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(ps.lookup(packed, idx)))


def test_serve_update_counts_accesses():
    w = jnp.zeros((8,), jnp.float32)
    idx = jnp.asarray([[0, 1], [1, 2]])
    cfg = qs.FQuantConfig().priority
    w2 = serve_update(w, idx, cfg)
    # (1-beta)*0 + beta*(alpha*0 + count)
    expect = np.zeros(8, np.float32)
    expect[[0, 1, 2]] = cfg.beta * np.asarray([1, 2, 1], np.float32)
    np.testing.assert_allclose(np.asarray(w2), expect, rtol=1e-6)


def test_online_server_end_to_end():
    """Cache-first serving + priority fold + periodic re-tier: lookups
    stay bit-identical to the live host packed store, which itself stays
    bit-identical to a full pack of the live QAT store."""
    st = _store(seed=5)
    srv = OnlineServer(st, CFG,
                       OnlineConfig(cache_rows=24, retier_every=3))
    for r in range(9):
        idx = jnp.asarray(drifting_zipf_batch((V,), 32, r, 9, drift=2.0,
                                              seed=9))
        # oracle BEFORE the call: observe() may re-tier the store after
        # serving this batch
        ref = np.asarray(ps.lookup(srv.host_packed, idx))
        rows = srv.lookup(idx)
        np.testing.assert_array_equal(np.asarray(rows), ref)
    assert srv.stats.requests == 9
    assert srv.stats.retiers == 3
    assert srv.stats.lookups == 9 * 32
    assert 0.0 <= srv.stats.hit_rate <= 1.0
    srv.retier()
    np.testing.assert_array_equal(
        np.asarray(ps.unpack(srv.host_packed)),
        np.asarray(ps.unpack(pack(srv.store, CFG))))


def test_drifting_zipf_batch_ranges_and_drift():
    cards = (50, 7, 3000)
    for r in (0, 5, 11):
        b = drifting_zipf_batch(cards, 64, r, 12, drift=3.0, seed=1)
        assert b.shape == (64, 3) and b.dtype == np.int32
        assert (b >= 0).all()
        assert (b < np.asarray(cards)).all()
    # stationary stream keeps the same hot id; drifting moves it
    def head(drift, r):
        b = drifting_zipf_batch(cards, 512, r, 12, drift=drift, seed=2)
        return np.bincount(b[:, 2], minlength=3000).argmax()
    assert head(0.0, 0) == head(0.0, 8)
    assert head(4.0, 8) == (head(4.0, 0) + 32) % 3000


def test_repack_delta_sharded_4way():
    """Under a 4-way mesh: shard -> unshard -> delta repack -> reshard
    serves bit-identically to a fresh full pack's sharded lookup."""
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from repro.core import FQuantConfig, pack
from repro.core import packed_store as ps
from repro.core import qat_store as qs
from repro.core.tiers import TierConfig
from repro.dist.packed import shard_packed, sharded_lookup, unshard_packed
from repro.serve import OnlineConfig, OnlineServer

V, D = 160, 24
CFG = FQuantConfig(tiers=TierConfig(t8=5.0, t16=50.0), stochastic=False)
rng = np.random.default_rng(0)
st = qs.init(jax.random.PRNGKey(0), V, D, scale=0.05)
st = st._replace(priority=jnp.asarray((rng.pareto(1.2, V) * 20)
                                      .astype(np.float32)))
st = st._replace(table=qs.snap(st.table, qs.current_tiers(st, CFG), CFG))

mesh = jax.make_mesh((4,), ("model",))
sp = shard_packed(pack(st, CFG), mesh)

# unshard trims padding back to the packed layout
back = unshard_packed(sp)
np.testing.assert_array_equal(np.asarray(ps.unpack(back)),
                              np.asarray(ps.unpack(pack(st, CFG))))

# perturb priorities, delta repack on host, reshard, serve
st2 = st._replace(priority=jnp.asarray(
    np.asarray(st.priority) * rng.uniform(0.05, 20, V).astype(np.float32)))
delta = ps.repack_delta(back, st2, CFG, np.arange(V))
full = pack(st2, CFG)
np.testing.assert_array_equal(np.asarray(ps.unpack(delta)),
                              np.asarray(ps.unpack(full)))
idx = jnp.asarray(rng.integers(0, V, 96).astype(np.int32))
out = sharded_lookup(shard_packed(delta, mesh), idx, mesh=mesh)
np.testing.assert_array_equal(np.asarray(out),
                              np.asarray(ps.lookup(full, idx)))

# OnlineServer drives the same machinery under the mesh
srv = OnlineServer(st, CFG, OnlineConfig(cache_rows=16, retier_every=2),
                   mesh=mesh)
for r in range(4):
    bidx = jnp.asarray(rng.integers(0, V, (8, 4)).astype(np.int32))
    ref = np.asarray(ps.lookup(srv.host_packed, bidx))
    rows = srv.lookup(bidx)
    np.testing.assert_array_equal(np.asarray(rows), ref)
assert srv.stats.retiers == 2
np.testing.assert_array_equal(
    np.asarray(ps.unpack(unshard_packed(srv.packed))),
    np.asarray(ps.unpack(pack(srv.store, CFG))))
print("ONLINE_SHARDED_OK")
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in ("src", env.get("PYTHONPATH", "")) if p)
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env,
                       cwd=os.path.join(os.path.dirname(__file__), ".."))
    assert "ONLINE_SHARDED_OK" in r.stdout, r.stderr[-2000:]
