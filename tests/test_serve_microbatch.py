"""Micro-batched serving pipeline: fixed-shape pad+mask fusion,
vectorised masked observe, request-counter re-tier cadence, and
bit-identity of the served rows with the packed-store oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import FQuantConfig, pack
from repro.core import packed_store as ps
from repro.core import qat_store as qs
from repro.core.tiers import TierConfig
from repro.serve import (
    MicroBatcher,
    OnlineConfig,
    OnlineServer,
    build_cache,
    cached_lookup,
    drifting_zipf_batch,
    run_microbatched_loop,
)

V, D = 160, 24
CFG = FQuantConfig(tiers=TierConfig(t8=5.0, t16=50.0), stochastic=False)


def _store(seed=0):
    rng = np.random.default_rng(seed)
    st = qs.init(jax.random.PRNGKey(seed), V, D, scale=0.05)
    pri = jnp.asarray((rng.pareto(1.2, V) * 20).astype(np.float32))
    st = st._replace(priority=pri)
    return st._replace(table=qs.snap(
        st.table, qs.current_tiers(st, CFG), CFG))


def test_microbatcher_fill_and_flush():
    mb = MicroBatcher(4, 3)
    assert mb.add([1, 2, 3]) is None
    assert mb.add([4, 5, 6]) is None
    assert len(mb) == 2
    tail = mb.flush()
    assert tail.count == 2
    assert tail.indices.shape == (4, 3)
    assert tail.indices.dtype == np.int32
    np.testing.assert_array_equal(tail.valid, [True, True, False, False])
    np.testing.assert_array_equal(tail.indices[2:], 0)  # row-0 padding
    assert len(mb) == 0 and mb.flush() is None

    full = None
    for i in range(4):
        got = mb.add([i, i, i])
        full = got or full
    assert full is not None and full.count == 4 and full.valid.all()
    np.testing.assert_array_equal(full.indices[:, 0], [0, 1, 2, 3])


def test_microbatcher_rejects_bad_shapes():
    with pytest.raises(ValueError):
        MicroBatcher(0, 3)
    mb = MicroBatcher(2, 3)
    with pytest.raises(ValueError):
        mb.add([1, 2])


def test_cached_lookup_valid_masks_hit_count():
    st = _store(1)
    packed = pack(st, CFG)
    cache = build_cache(packed, st.priority, 32)
    hot = np.asarray(cache.ids)[:4]
    idx = jnp.asarray(np.stack([hot, hot]).T)          # (4, 2) all hits
    valid = jnp.asarray([True, True, False, False])
    out, hits = cached_lookup(packed, cache, idx, valid=valid[:, None])
    assert int(hits) == 4                               # 2 rows x 2 cols
    # masking changes accounting only, never the gathered rows
    out_all, hits_all = cached_lookup(packed, cache, idx)
    assert int(hits_all) == 8
    np.testing.assert_array_equal(np.asarray(out), np.asarray(out_all))


def test_observe_masked_equals_unpadded_fold():
    """A padded micro-batch folds exactly like its live prefix."""
    st = _store(2)
    a = OnlineServer(st, CFG, OnlineConfig(retier_every=0))
    b = OnlineServer(st, CFG, OnlineConfig(retier_every=0))
    idx = np.array([[3, 4], [7, 8], [0, 0], [0, 0]], np.int32)
    valid = np.array([True, True, False, False])
    a.observe(jnp.asarray(idx), 1, valid=valid[:, None], count=2)
    b.observe(jnp.asarray(idx[:2]), 1, count=2)
    np.testing.assert_array_equal(np.asarray(a.store.priority),
                                  np.asarray(b.store.priority))
    assert a.stats.requests == b.stats.requests == 2
    assert a.stats.lookups == b.stats.lookups == 4
    assert a.stats.hits == b.stats.hits == 1


def test_observe_count_crossing_triggers_retier():
    """count > 1 fires the re-tier whenever the request counter crosses
    a retier_every boundary — same boundaries as count=1 serving."""
    st = _store(3)
    srv = OnlineServer(st, CFG, OnlineConfig(retier_every=4))
    idx = jnp.asarray(np.zeros((3, 2), np.int32))
    fired = []
    for _ in range(4):
        srv.observe(idx, count=3)      # requests: 3, 6, 9, 12
        fired.append(srv.stats.retiers)
    assert fired == [0, 1, 2, 3]       # crossings at 4, 8, 12

    srv1 = OnlineServer(st, CFG, OnlineConfig(retier_every=4))
    for _ in range(12):
        srv1.observe(idx[:1], count=1)
    assert srv1.stats.retiers == 3     # identical cadence per-request


def test_run_microbatched_loop_serves_bit_identical_rows():
    """End-to-end: every micro-batch's gathered rows equal the oracle
    lookup on the live host store; stats line up with the stream."""
    st = _store(4)
    srv = OnlineServer(st, CFG,
                       OnlineConfig(cache_rows=24, retier_every=8))
    served = []

    def serve_fn(mb):
        idx = jnp.asarray(mb.indices)
        ref = np.asarray(ps.lookup(srv.host_packed, idx))
        rows, hits = cached_lookup(srv.packed, srv.cache, idx,
                                   srv.lookup_fn(),
                                   valid=jnp.asarray(mb.valid)[:, None])
        np.testing.assert_array_equal(np.asarray(rows), ref)
        srv.observe(idx, int(hits), valid=mb.valid[:, None],
                    count=mb.count)
        served.append(mb.count)
        return rows

    result = run_microbatched_loop(
        srv, serve_fn,
        lambda r: drifting_zipf_batch((V, V), 1, r, 22, drift=2.0,
                                      seed=3)[0],
        requests=22, serve_batch=4)
    assert sum(served) == 22
    assert served[-1] == 2                  # padded tail batch
    assert srv.stats.requests == 22
    assert srv.stats.lookups == 44          # 22 requests x 2 fields
    assert srv.stats.retiers == 2           # crossings at 8, 16
    assert result.qps > 0 and result.steady_qps > 0
    assert len(result.lat_s) == 6           # ceil(22 / 4) batches
    # post-stream: the delta-repacked store still equals a full pack
    np.testing.assert_array_equal(
        np.asarray(ps.unpack(srv.host_packed)),
        np.asarray(ps.unpack(pack(srv.store, CFG))))


def test_microbatch_stream_independent_of_fusion_factor():
    """The same seed yields the same request sequence whatever the
    micro-batch capacity — QPS sweeps compare like-for-like."""
    reqs = [drifting_zipf_batch((V, 31), 1, r, 16, drift=3.0, seed=7)[0]
            for r in range(16)]
    for sb in (1, 4, 8):
        batcher = MicroBatcher(sb, 2)
        got = []
        for r in reqs:
            out = batcher.add(r)
            if out is not None:
                got.append(out.indices[:out.count])
        tail = batcher.flush()
        if tail is not None:
            got.append(tail.indices[:tail.count])
        np.testing.assert_array_equal(np.concatenate(got),
                                      np.stack(reqs))
