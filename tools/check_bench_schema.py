#!/usr/bin/env python
"""Validate a benchmark JSON file (``bench_qps/v1`` / ``bench_hier/v1``).

    python tools/check_bench_schema.py [BENCH_qps.json | BENCH_hier.json]

The schemas are the stable contract between PRs: benchmarks emit them
(``benchmarks/qps.py --online --serve-batch ...``,
``benchmarks/qps_sharded.py``, ``benchmarks/run.py --emit``,
``benchmarks/hier.py``), CI validates them, future PRs diff the sweep
entries for regressions.  Documented in docs/serving.md and
docs/storage.md.  The schema is picked from the record's ``"schema"``
key.

Exit 0 = valid; exit 1 prints every violation found.
"""

from __future__ import annotations

import json
import numbers
import sys

QPS_TOP = {
    "schema": str,
    "benchmark": str,
    "requests": numbers.Integral,
    "cache_rows": numbers.Integral,
    "retier_every": numbers.Integral,
    "drift": numbers.Real,
    "packed_fp32_ratio": numbers.Real,
    "bytes_per_request_fp32": numbers.Integral,
    "bytes_per_request_packed": numbers.Integral,
    "sweep": list,
}

QPS_SWEEP = {
    "serve_batch": numbers.Integral,
    "qps": numbers.Real,
    "steady_qps": numbers.Real,
    "p50_us": numbers.Real,
    "p99_us": numbers.Real,
    "requests": numbers.Integral,
    "lookups": numbers.Integral,
    "hits": numbers.Integral,
    "cache_hit_rate": numbers.Real,
    "retiers": numbers.Integral,
    "rows_moved": numbers.Integral,
    "bytes_per_request_fp32": numbers.Integral,
    "bytes_per_request_packed": numbers.Integral,
}

HIER_TOP = {
    "schema": str,
    "benchmark": str,
    "requests": numbers.Integral,
    "serve_batch": numbers.Integral,
    "cache_rows": numbers.Integral,
    "retier_every": numbers.Integral,
    "drift": numbers.Real,
    "packed_fp32_ratio": numbers.Real,
    "full_store_bytes": numbers.Integral,
    "sweep": list,
}

HIER_SWEEP = {
    "hbm_budget_fraction": numbers.Real,
    "hot_rows": numbers.Integral,
    "warm_rows": numbers.Integral,
    "cold_rows": numbers.Integral,
    "qps": numbers.Real,
    "steady_qps": numbers.Real,
    "p50_us": numbers.Real,
    "p99_us": numbers.Real,
    "lookups": numbers.Integral,
    "cache_hit_rate": numbers.Real,
    "hier_miss_rate": numbers.Real,
    "warm_hits": numbers.Integral,
    "cold_hits": numbers.Integral,
    "staged_rows": numbers.Integral,
    "migrations": numbers.Integral,
    "promoted": numbers.Integral,
    "demoted": numbers.Integral,
}


def _check_keys(obj: dict, spec: dict, where: str, errors: list) -> None:
    for key, typ in spec.items():
        if key not in obj:
            errors.append(f"{where}: missing key {key!r}")
        elif isinstance(obj[key], bool) or not isinstance(obj[key], typ):
            errors.append(f"{where}: {key!r} should be {typ.__name__}, "
                          f"got {type(obj[key]).__name__}")


def _check_sweep(rec: dict, spec: dict, errors: list) -> list[dict]:
    sweep = rec.get("sweep")
    entries = []
    if isinstance(sweep, list):
        if not sweep:
            errors.append("sweep: empty")
        for i, entry in enumerate(sweep):
            if not isinstance(entry, dict):
                errors.append(f"sweep[{i}]: not an object")
                continue
            _check_keys(entry, spec, f"sweep[{i}]", errors)
            entries.append(entry)
    return entries


def _validate_qps(rec: dict) -> list[str]:
    errors: list[str] = []
    _check_keys(rec, QPS_TOP, "top-level", errors)
    entries = _check_sweep(rec, QPS_SWEEP, errors)
    batches = [e.get("serve_batch") for e in entries]
    if len(set(batches)) != len(batches):
        errors.append("sweep: duplicate serve_batch entries")
    # the whole point of the record: byte traffic must not depend
    # on the fusion factor
    packed = {e.get("bytes_per_request_packed") for e in entries}
    if len(packed) > 1:
        errors.append("sweep: bytes_per_request_packed differs "
                      f"across serve_batch values: {sorted(packed)}")
    return errors


def _validate_hier(rec: dict) -> list[str]:
    errors: list[str] = []
    _check_keys(rec, HIER_TOP, "top-level", errors)
    entries = _check_sweep(rec, HIER_SWEEP, errors)
    fracs = [e.get("hbm_budget_fraction") for e in entries]
    if len(set(fracs)) != len(fracs):
        errors.append("sweep: duplicate hbm_budget_fraction entries")
    # the whole point of the record: a bigger HBM budget holds a
    # superset of a smaller one's hot rows (prefix placement), so the
    # spill miss rate must fall (weakly) as the budget fraction rises
    ok = [e for e in entries
          if isinstance(e.get("hbm_budget_fraction"), numbers.Real)
          and isinstance(e.get("hier_miss_rate"), numbers.Real)]
    ok.sort(key=lambda e: e["hbm_budget_fraction"])
    for lo, hi in zip(ok, ok[1:]):
        if hi["hier_miss_rate"] > lo["hier_miss_rate"] + 1e-9:
            errors.append(
                "sweep: hier_miss_rate rises with the HBM budget "
                f"fraction ({lo['hbm_budget_fraction']}: "
                f"{lo['hier_miss_rate']} -> "
                f"{hi['hbm_budget_fraction']}: {hi['hier_miss_rate']})")
    return errors


SCHEMAS = {
    "bench_qps/v1": _validate_qps,
    "bench_hier/v1": _validate_hier,
}


def validate(rec: dict) -> list[str]:
    schema = rec.get("schema")
    fn = SCHEMAS.get(schema)
    if fn is None:
        return [f"top-level: schema is {schema!r}, expected one of "
                f"{sorted(SCHEMAS)}"]
    return fn(rec)


def main() -> int:
    path = sys.argv[1] if len(sys.argv) > 1 else "BENCH_qps.json"
    try:
        with open(path) as f:
            rec = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"{path}: unreadable: {e}")
        return 1
    errors = validate(rec)
    for err in errors:
        print(f"{path}: {err}")
    if not errors:
        n = len(rec["sweep"])
        print(f"{path}: valid {rec['schema']} ({n} sweep entries)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
