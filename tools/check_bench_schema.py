#!/usr/bin/env python
"""Validate a ``bench_qps/v1`` JSON file (BENCH_qps.json).

    python tools/check_bench_schema.py [BENCH_qps.json]

The schema is the stable contract between PRs: benchmarks emit it
(``benchmarks/qps.py --online --serve-batch ...`` or
``benchmarks/run.py --emit``), CI validates it, future PRs diff the
sweep entries for regressions.  Documented in docs/serving.md.

Exit 0 = valid; exit 1 prints every violation found.
"""

from __future__ import annotations

import json
import numbers
import sys

TOP_KEYS = {
    "schema": str,
    "benchmark": str,
    "requests": numbers.Integral,
    "cache_rows": numbers.Integral,
    "retier_every": numbers.Integral,
    "drift": numbers.Real,
    "packed_fp32_ratio": numbers.Real,
    "bytes_per_request_fp32": numbers.Integral,
    "bytes_per_request_packed": numbers.Integral,
    "sweep": list,
}

SWEEP_KEYS = {
    "serve_batch": numbers.Integral,
    "qps": numbers.Real,
    "steady_qps": numbers.Real,
    "p50_us": numbers.Real,
    "p99_us": numbers.Real,
    "requests": numbers.Integral,
    "lookups": numbers.Integral,
    "hits": numbers.Integral,
    "cache_hit_rate": numbers.Real,
    "retiers": numbers.Integral,
    "rows_moved": numbers.Integral,
    "bytes_per_request_fp32": numbers.Integral,
    "bytes_per_request_packed": numbers.Integral,
}


def _check_keys(obj: dict, spec: dict, where: str, errors: list) -> None:
    for key, typ in spec.items():
        if key not in obj:
            errors.append(f"{where}: missing key {key!r}")
        elif isinstance(obj[key], bool) or not isinstance(obj[key], typ):
            errors.append(f"{where}: {key!r} should be {typ.__name__}, "
                          f"got {type(obj[key]).__name__}")


def validate(rec: dict) -> list[str]:
    errors: list[str] = []
    _check_keys(rec, TOP_KEYS, "top-level", errors)
    if rec.get("schema") != "bench_qps/v1":
        errors.append(f"top-level: schema is {rec.get('schema')!r}, "
                      "expected 'bench_qps/v1'")
    sweep = rec.get("sweep")
    if isinstance(sweep, list):
        if not sweep:
            errors.append("sweep: empty")
        for i, entry in enumerate(sweep):
            if not isinstance(entry, dict):
                errors.append(f"sweep[{i}]: not an object")
                continue
            _check_keys(entry, SWEEP_KEYS, f"sweep[{i}]", errors)
        batches = [e.get("serve_batch") for e in sweep
                   if isinstance(e, dict)]
        if len(set(batches)) != len(batches):
            errors.append("sweep: duplicate serve_batch entries")
        # the whole point of the record: byte traffic must not depend
        # on the fusion factor
        packed = {e.get("bytes_per_request_packed") for e in sweep
                  if isinstance(e, dict)}
        if len(packed) > 1:
            errors.append("sweep: bytes_per_request_packed differs "
                          f"across serve_batch values: {sorted(packed)}")
    return errors


def main() -> int:
    path = sys.argv[1] if len(sys.argv) > 1 else "BENCH_qps.json"
    try:
        with open(path) as f:
            rec = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"{path}: unreadable: {e}")
        return 1
    errors = validate(rec)
    for err in errors:
        print(f"{path}: {err}")
    if not errors:
        n = len(rec["sweep"])
        print(f"{path}: valid bench_qps/v1 ({n} sweep entries)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
