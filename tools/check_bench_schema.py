#!/usr/bin/env python
"""Validate benchmark / metrics JSON files (``bench_qps/v1`` /
``bench_hier/v1`` / ``bench_pipeline/v1`` / ``bench_kernel/v1`` /
``metrics_snapshot/v1``).

    python tools/check_bench_schema.py [FILE ...]

Accepts any number of files (default ``BENCH_qps.json``).  ``.jsonl``
files are validated line by line — every line must be a valid record
(this is how ``--metrics-out`` snapshot streams are checked).

The schemas are the stable contract between PRs: benchmarks emit them
(``benchmarks/qps.py --online --serve-batch ...``,
``benchmarks/qps_sharded.py``, ``benchmarks/run.py --emit``,
``benchmarks/hier.py``, ``benchmarks/kernels.py --emit``,
``repro.launch.pipeline --emit``), the launch
drivers emit metrics snapshots (``--metrics-out``), CI validates them,
future PRs diff the entries for regressions.  Documented in
docs/serving.md, docs/storage.md, docs/training.md and
docs/observability.md.  The schema is picked from the record's
``"schema"`` key.

Exit 0 = valid; exit 1 prints every violation found.
"""

from __future__ import annotations

import json
import numbers
import sys

QPS_TOP = {
    "schema": str,
    "benchmark": str,
    "requests": numbers.Integral,
    "cache_rows": numbers.Integral,
    "retier_every": numbers.Integral,
    "drift": numbers.Real,
    "retier_async": bool,
    "packed_fp32_ratio": numbers.Real,
    "bytes_per_request_fp32": numbers.Integral,
    "bytes_per_request_packed": numbers.Integral,
    "sweep": list,
}

# histogram-derived latency columns every online sweep entry carries
# (serve.loop.LoopResult.as_dict); p99_retier_attributed is the
# fraction of the p99 tail's wall time spent inside retier/migrate,
# p99_while_retiering the p99 over only the warm batches that
# overlapped shadow build / swap work (0.0 when there were none)
LATENCY_KEYS = {
    "p95_us": numbers.Real,
    "latency_p50": numbers.Real,
    "latency_p95": numbers.Real,
    "latency_p99": numbers.Real,
    "p99_retier_attributed": numbers.Real,
    "p99_while_retiering": numbers.Real,
}

# with --retier-async the re-tier runs as a chunked shadow build off
# the request path; the whole point is the tail, so entries must hold
# the p99 (overall AND during re-tiering) to this multiple of the p50
RETIER_TAIL_BUDGET = 10.0

QPS_SWEEP = {
    "serve_batch": numbers.Integral,
    "qps": numbers.Real,
    "steady_qps": numbers.Real,
    "p50_us": numbers.Real,
    "p99_us": numbers.Real,
    "requests": numbers.Integral,
    "lookups": numbers.Integral,
    "hits": numbers.Integral,
    "cache_hit_rate": numbers.Real,
    "retiers": numbers.Integral,
    "rows_moved": numbers.Integral,
    "swaps": numbers.Integral,
    "shadow_builds": numbers.Integral,
    "bytes_per_request_fp32": numbers.Integral,
    "bytes_per_request_packed": numbers.Integral,
    **LATENCY_KEYS,
}

HIER_TOP = {
    "schema": str,
    "benchmark": str,
    "requests": numbers.Integral,
    "serve_batch": numbers.Integral,
    "cache_rows": numbers.Integral,
    "retier_every": numbers.Integral,
    "drift": numbers.Real,
    "retier_async": bool,
    "packed_fp32_ratio": numbers.Real,
    "full_store_bytes": numbers.Integral,
    "sweep": list,
}

HIER_SWEEP = {
    "hbm_budget_fraction": numbers.Real,
    "hot_rows": numbers.Integral,
    "warm_rows": numbers.Integral,
    "cold_rows": numbers.Integral,
    "qps": numbers.Real,
    "steady_qps": numbers.Real,
    "p50_us": numbers.Real,
    "p99_us": numbers.Real,
    "lookups": numbers.Integral,
    "cache_hit_rate": numbers.Real,
    "hier_miss_rate": numbers.Real,
    "warm_hits": numbers.Integral,
    "cold_hits": numbers.Integral,
    "staged_rows": numbers.Integral,
    "migrations": numbers.Integral,
    "promoted": numbers.Integral,
    "demoted": numbers.Integral,
    "swaps": numbers.Integral,
    "shadow_builds": numbers.Integral,
    **LATENCY_KEYS,
}


def _check_keys(obj: dict, spec: dict, where: str, errors: list) -> None:
    for key, typ in spec.items():
        if key not in obj:
            errors.append(f"{where}: missing key {key!r}")
            continue
        val = obj[key]
        if typ is bool:
            if not isinstance(val, bool):
                errors.append(f"{where}: {key!r} should be bool, "
                              f"got {type(val).__name__}")
        elif isinstance(val, bool) or not isinstance(val, typ):
            errors.append(f"{where}: {key!r} should be {typ.__name__}, "
                          f"got {type(val).__name__}")


def _check_sweep(rec: dict, spec: dict, errors: list) -> list[dict]:
    sweep = rec.get("sweep")
    entries = []
    if isinstance(sweep, list):
        if not sweep:
            errors.append("sweep: empty")
        for i, entry in enumerate(sweep):
            if not isinstance(entry, dict):
                errors.append(f"sweep[{i}]: not an object")
                continue
            _check_keys(entry, spec, f"sweep[{i}]", errors)
            entries.append(entry)
    return entries


def _is_num(v) -> bool:
    return isinstance(v, numbers.Real) and not isinstance(v, bool)


def _check_latency(entries: list[dict], errors: list) -> None:
    """Shared latency-column invariants for online sweep entries."""
    for i, e in enumerate(entries):
        att = e.get("p99_retier_attributed")
        if _is_num(att) and not 0.0 <= att <= 1.0:
            errors.append(f"sweep[{i}]: p99_retier_attributed {att} "
                          "out of [0, 1]")
        ps = [e.get(k) for k in ("latency_p50", "latency_p95",
                                 "latency_p99")]
        if all(_is_num(p) for p in ps) and \
                not (ps[0] <= ps[1] + 1e-9 <= ps[2] + 2e-9):
            errors.append(f"sweep[{i}]: latency percentiles not "
                          f"monotone (p50 {ps[0]} / p95 {ps[1]} / "
                          f"p99 {ps[2]})")


def _check_tail_budget(rec: dict, entries: list[dict],
                       errors: list) -> None:
    """Async re-tiering's contract: the p99 tail — overall and over the
    batches that overlapped shadow work — stays within
    ``RETIER_TAIL_BUDGET`` x the p50.  Enforced only on records that
    actually re-tiered asynchronously (``retier_async`` true and a
    positive cadence); the synchronous path is what this budget exists
    to indict."""
    if rec.get("retier_async") is not True:
        return
    cadence = rec.get("retier_every")
    if not (isinstance(cadence, numbers.Integral) and cadence > 0):
        return
    for i, e in enumerate(entries):
        p50 = e.get("latency_p50")
        if not _is_num(p50) or p50 <= 0:
            continue
        for key in ("latency_p99", "p99_while_retiering"):
            val = e.get(key)
            if _is_num(val) and val > RETIER_TAIL_BUDGET * p50:
                errors.append(
                    f"sweep[{i}]: {key} {val} exceeds the async "
                    f"re-tier tail budget ({RETIER_TAIL_BUDGET:g}x "
                    f"p50 = {RETIER_TAIL_BUDGET * p50:.1f})")


def _validate_qps(rec: dict) -> list[str]:
    errors: list[str] = []
    _check_keys(rec, QPS_TOP, "top-level", errors)
    entries = _check_sweep(rec, QPS_SWEEP, errors)
    _check_latency(entries, errors)
    _check_tail_budget(rec, entries, errors)
    batches = [e.get("serve_batch") for e in entries]
    if len(set(batches)) != len(batches):
        errors.append("sweep: duplicate serve_batch entries")
    # the whole point of the record: byte traffic must not depend
    # on the fusion factor
    packed = {e.get("bytes_per_request_packed") for e in entries}
    if len(packed) > 1:
        errors.append("sweep: bytes_per_request_packed differs "
                      f"across serve_batch values: {sorted(packed)}")
    return errors


def _validate_hier(rec: dict) -> list[str]:
    errors: list[str] = []
    _check_keys(rec, HIER_TOP, "top-level", errors)
    entries = _check_sweep(rec, HIER_SWEEP, errors)
    _check_latency(entries, errors)
    _check_tail_budget(rec, entries, errors)
    fracs = [e.get("hbm_budget_fraction") for e in entries]
    if len(set(fracs)) != len(fracs):
        errors.append("sweep: duplicate hbm_budget_fraction entries")
    # the whole point of the record: a bigger HBM budget holds a
    # superset of a smaller one's hot rows (prefix placement), so the
    # spill miss rate must fall (weakly) as the budget fraction rises
    ok = [e for e in entries
          if isinstance(e.get("hbm_budget_fraction"), numbers.Real)
          and isinstance(e.get("hier_miss_rate"), numbers.Real)]
    ok.sort(key=lambda e: e["hbm_budget_fraction"])
    for lo, hi in zip(ok, ok[1:]):
        if hi["hier_miss_rate"] > lo["hier_miss_rate"] + 1e-9:
            errors.append(
                "sweep: hier_miss_rate rises with the HBM budget "
                f"fraction ({lo['hbm_budget_fraction']}: "
                f"{lo['hier_miss_rate']} -> "
                f"{hi['hbm_budget_fraction']}: {hi['hier_miss_rate']})")
    return errors


PIPELINE_TOP = {
    "schema": str,
    "benchmark": str,
    "arch": str,
    "mesh": numbers.Integral,
    "train_steps": numbers.Integral,
    "batch": numbers.Integral,
    "train_loss_first": numbers.Real,
    "train_loss_last": numbers.Real,
    "gradcheck_max_abs_err": numbers.Real,
    "fields_total": numbers.Integral,
    "fields_pruned": numbers.Integral,
    "kept_memory_fraction": numbers.Real,
    "tier_rows_int8": numbers.Integral,
    "tier_rows_half": numbers.Integral,
    "tier_rows_fp32": numbers.Integral,
    "bytes_fp32": numbers.Integral,
    "bytes_packed": numbers.Integral,
    "compression_ratio": numbers.Real,
    "eval_loss_fp32": numbers.Real,
    "eval_loss_packed": numbers.Real,
    "eval_auc_fp32": numbers.Real,
    "eval_auc_packed": numbers.Real,
    "serve_requests": numbers.Integral,
    "serve_batch": numbers.Integral,
    "steady_qps": numbers.Real,
    "cache_hit_rate": numbers.Real,
    "retiers": numbers.Integral,
    "verify_pack_bit_identical": bool,
    "verify_serve_bit_identical": bool,
    "verify_grad_fp32_tolerance": bool,
    "verify_accum_checkpointed": bool,
    "stage_seconds": dict,
}

PIPELINE_STAGES = ("train", "prune", "quantize", "pack", "serve")


def _validate_pipeline(rec: dict) -> list[str]:
    errors: list[str] = []
    _check_keys(rec, PIPELINE_TOP, "top-level", errors)
    if errors:
        return errors
    # the whole point of the record: the pipeline must actually
    # compress, and every end-to-end verification must have held
    if rec["bytes_packed"] >= rec["bytes_fp32"]:
        errors.append("bytes_packed >= bytes_fp32: pipeline did not "
                      "compress")
    ratio = rec["bytes_packed"] / max(rec["bytes_fp32"], 1)
    if abs(rec["compression_ratio"] - ratio) > 1e-3:
        errors.append(f"compression_ratio {rec['compression_ratio']} "
                      f"inconsistent with byte counts ({ratio:.4f})")
    for key in ("verify_pack_bit_identical", "verify_serve_bit_identical",
                "verify_grad_fp32_tolerance",
                "verify_accum_checkpointed"):
        if rec[key] is not True:
            errors.append(f"{key}: must be true")
    if not 0 <= rec["fields_pruned"] < rec["fields_total"]:
        errors.append("fields_pruned out of range")
    # the tolerance judgement itself is the driver's (relative to the
    # gradient scale; verify_grad_fp32_tolerance above) — here only
    # sanity-check the recorded error is a valid measurement
    if rec["gradcheck_max_abs_err"] < 0:
        errors.append("gradcheck_max_abs_err negative")
    if not 0.0 <= rec["cache_hit_rate"] <= 1.0:
        errors.append("cache_hit_rate out of [0, 1]")
    tiers = (rec["tier_rows_int8"], rec["tier_rows_half"],
             rec["tier_rows_fp32"])
    if min(tiers) < 0 or sum(tiers) <= 0:
        errors.append("tier_rows_* invalid")
    if rec["mesh"] < 1:
        errors.append("mesh must be >= 1")
    stages = rec["stage_seconds"]
    for stage in PIPELINE_STAGES:
        sec = stages.get(stage)
        if not isinstance(sec, numbers.Real) or isinstance(sec, bool) \
                or sec < 0:
            errors.append(f"stage_seconds[{stage!r}] missing or "
                          "invalid")
    return errors


METRICS_TOP = {
    "schema": str,
    "seq": numbers.Integral,
    "ticks": numbers.Integral,
    "counters": dict,
    "gauges": dict,
    "histograms": dict,
}

METRICS_HIST = {
    "count": numbers.Integral,
    "sum": numbers.Real,
    "min": numbers.Real,
    "max": numbers.Real,
    "p50": numbers.Real,
    "p95": numbers.Real,
    "p99": numbers.Real,
    "buckets": dict,
}


def _validate_metrics(rec: dict) -> list[str]:
    """One ``metrics_snapshot/v1`` record (one ``--metrics-out`` JSONL
    line): name -> number maps plus per-histogram summaries whose
    percentiles must be ordered inside the [min, max] envelope and
    whose sparse bucket counts must re-add to ``count`` (the offline
    re-merge contract)."""
    errors: list[str] = []
    _check_keys(rec, METRICS_TOP, "top-level", errors)
    if errors:
        return errors
    for kind in ("counters", "gauges"):
        for name, val in rec[kind].items():
            if not _is_num(val):
                errors.append(f"{kind}[{name!r}]: not a number")
        if kind == "counters":
            for name, val in rec[kind].items():
                if _is_num(val) and val < 0:
                    errors.append(f"counters[{name!r}]: negative")
    for name, h in rec["histograms"].items():
        where = f"histograms[{name!r}]"
        if not isinstance(h, dict):
            errors.append(f"{where}: not an object")
            continue
        _check_keys(h, METRICS_HIST, where, errors)
        if any(e.startswith(where) for e in errors):
            continue
        n = h["count"]
        if n < 0:
            errors.append(f"{where}: negative count")
        bsum = 0
        for idx, c in h["buckets"].items():
            if not (isinstance(c, numbers.Integral) and c > 0
                    and str(idx).isdigit()):
                errors.append(f"{where}: bad bucket {idx!r}: {c!r}")
                break
            bsum += int(c)
        else:
            if bsum != n:
                errors.append(f"{where}: bucket counts sum to {bsum}, "
                              f"count is {n}")
        if n > 0 and not (h["min"] - 1e-9 <= h["p50"]
                          <= h["p95"] + 1e-9 <= h["p99"] + 2e-9
                          <= h["max"] + 3e-9):
            errors.append(
                f"{where}: percentiles not ordered within [min, max] "
                f"(min {h['min']} p50 {h['p50']} p95 {h['p95']} "
                f"p99 {h['p99']} max {h['max']})")
    return errors


KERNEL_TOP = {
    "schema": str,
    "benchmark": str,
    "backend": str,
    "interpret": bool,
    "hbm_peak_gbs": numbers.Real,
    "sweep": list,
}

KERNEL_SWEEP = {
    "kernel": str,
    "dtype": str,
    "b": numbers.Integral,
    "k": numbers.Integral,
    "d": numbers.Integral,
    "h": numbers.Integral,
    "block_analytic": list,
    "analytic_us": numbers.Real,
    "block_measured": list,
    "measured_us": numbers.Real,
    "speedup": numbers.Real,
    "bytes_moved": numbers.Integral,
    "achieved_gbs": numbers.Real,
    "peak_fraction": numbers.Real,
}

# timing jitter allowance for the measured-vs-analytic invariant;
# the analytic pick is itself a sweep candidate, so only noise between
# two timings of the same tiling can push "measured" past "analytic"
KERNEL_TUNE_EPS = 1e-6


def _validate_kernel(rec: dict) -> list[str]:
    """``bench_kernel/v1`` (benchmarks/kernels.py): measured tiling
    sweeps.  The whole point of the record: the measured-autotune
    winner is at least as fast as the analytic pick on EVERY swept
    shape — the sweep includes the analytic pick as a candidate, so a
    violation means the sweep/cache machinery regressed, not that the
    analytic model is good."""
    errors: list[str] = []
    _check_keys(rec, KERNEL_TOP, "top-level", errors)
    entries = _check_sweep(rec, KERNEL_SWEEP, errors)
    seen = set()
    for i, e in enumerate(entries):
        key = (e.get("kernel"), e.get("dtype"), e.get("b"),
               e.get("k"), e.get("d"), e.get("h"))
        if key in seen:
            errors.append(f"sweep[{i}]: duplicate shape entry {key}")
        seen.add(key)
        ua, um = e.get("analytic_us"), e.get("measured_us")
        if _is_num(ua) and _is_num(um):
            if um <= 0 or ua <= 0:
                errors.append(f"sweep[{i}]: non-positive timing "
                              f"(analytic {ua}, measured {um})")
            elif um > ua * (1.0 + KERNEL_TUNE_EPS):
                errors.append(
                    f"sweep[{i}]: measured tiling slower than the "
                    f"analytic pick ({e.get('kernel')} b={e.get('b')} "
                    f"k={e.get('k')} d={e.get('d')}: measured {um}us "
                    f"> analytic {ua}us)")
            sp = e.get("speedup")
            if _is_num(sp) and um > 0 and abs(sp - ua / um) > 1e-3 * sp:
                errors.append(f"sweep[{i}]: speedup {sp} inconsistent "
                              f"with timings ({ua / um:.4f})")
        for kk in ("block_analytic", "block_measured"):
            blk = e.get(kk)
            if isinstance(blk, list) and not (
                    len(blk) == 2
                    and all(isinstance(x, numbers.Integral)
                            and not isinstance(x, bool) and x >= 1
                            for x in blk)):
                errors.append(f"sweep[{i}]: {kk} must be two ints "
                              f">= 1, got {blk!r}")
        for kk in ("bytes_moved", "achieved_gbs", "peak_fraction"):
            v = e.get(kk)
            if _is_num(v) and v <= 0:
                errors.append(f"sweep[{i}]: {kk} must be positive, "
                              f"got {v}")
    return errors


FLEET_TOP = {
    "schema": str,
    "benchmark": str,
    "arch": str,
    "policy": str,
    "serve_batch": numbers.Integral,
    "requests": numbers.Integral,
    "merge_every": numbers.Integral,
    "retier_every": numbers.Integral,
    "retier_async": bool,
    "drift": numbers.Real,
    "sweep": list,
}

FLEET_SWEEP = {
    "replicas": numbers.Integral,
    "policy": str,
    "aggregate_qps": numbers.Real,
    "per_replica_qps": list,
    "p50_us": numbers.Real,
    "p95_us": numbers.Real,
    "p99_us": numbers.Real,
    "route_p50_us": numbers.Real,
    "router_overhead_frac": numbers.Real,
    "requests": numbers.Integral,
    "merges": numbers.Integral,
    "divergence": numbers.Real,
    "divergence_premerge": numbers.Real,
    "swaps_colocated": numbers.Integral,
}

# the routing decision must be noise next to the work it routes:
# route-time p50 stays under this fraction of the per-request p50
FLEET_ROUTER_BUDGET = 0.10

# aggregate capacity QPS must not DROP as replicas are added, up to
# this replica count (beyond it, per-replica request starvation on the
# fixed smoke stream makes steady windows too thin to gate on)
FLEET_MONOTONE_UPTO = 4


def _validate_fleet(rec: dict) -> list[str]:
    """``bench_fleet/v1`` (repro.launch.fleet): replica-scaling sweep.
    The load-bearing invariants: fleet capacity is monotone in replica
    count (up to ``FLEET_MONOTONE_UPTO``), the router's decision cost
    stays under ``FLEET_ROUTER_BUDGET`` of the per-request p50, fleet
    percentiles are ordered (they come from the exact cross-replica
    bucket merge — a violation means the merge regressed), and the
    periodic priority merge drives cross-replica divergence DOWN."""
    errors: list[str] = []
    _check_keys(rec, FLEET_TOP, "top-level", errors)
    entries = _check_sweep(rec, FLEET_SWEEP, errors)
    reps = [e.get("replicas") for e in entries]
    if len(set(reps)) != len(reps):
        errors.append("sweep: duplicate replica-count entries")
    for i, e in enumerate(entries):
        ps = [e.get(k) for k in ("p50_us", "p95_us", "p99_us")]
        if all(_is_num(p) for p in ps) and \
                not (ps[0] <= ps[1] + 1e-9 <= ps[2] + 2e-9):
            errors.append(f"sweep[{i}]: fleet percentiles not monotone "
                          f"(p50 {ps[0]} / p95 {ps[1]} / p99 {ps[2]})")
        frac = e.get("router_overhead_frac")
        if _is_num(frac) and not 0.0 <= frac < FLEET_ROUTER_BUDGET:
            errors.append(
                f"sweep[{i}]: router_overhead_frac {frac} outside "
                f"[0, {FLEET_ROUTER_BUDGET}) — the routing decision "
                "must be noise next to the per-request p50")
        per = e.get("per_replica_qps")
        n = e.get("replicas")
        if isinstance(per, list) and isinstance(n, numbers.Integral):
            if len(per) != n:
                errors.append(f"sweep[{i}]: per_replica_qps has "
                              f"{len(per)} entries for {n} replicas")
            if not all(_is_num(q) and q > 0 for q in per):
                errors.append(f"sweep[{i}]: per_replica_qps entries "
                              "must be positive numbers")
        d, dp = e.get("divergence"), e.get("divergence_premerge")
        if _is_num(d) and d < 0:
            errors.append(f"sweep[{i}]: divergence negative")
        if _is_num(d) and _is_num(dp) and e.get("merges", 0) \
                and isinstance(n, numbers.Integral) and n > 1 \
                and d > dp + 1e-9:
            errors.append(
                f"sweep[{i}]: divergence {d} above pre-merge "
                f"divergence {dp} — the periodic Eq. 7 merge must "
                "drive it down")
    ok = [e for e in entries
          if isinstance(e.get("replicas"), numbers.Integral)
          and _is_num(e.get("aggregate_qps"))]
    ok.sort(key=lambda e: e["replicas"])
    for lo, hi in zip(ok, ok[1:]):
        if hi["replicas"] > FLEET_MONOTONE_UPTO:
            break
        if hi["aggregate_qps"] + 1e-9 < lo["aggregate_qps"]:
            errors.append(
                "sweep: aggregate_qps drops with replica count "
                f"({lo['replicas']}: {lo['aggregate_qps']} -> "
                f"{hi['replicas']}: {hi['aggregate_qps']})")
    return errors


HASH_TOP = {
    "schema": str,
    "benchmark": str,
    "vocab": numbers.Integral,
    "dim": numbers.Integral,
    "chunk_dim": numbers.Integral,
    "num_hashes": numbers.Integral,
    "train_steps": numbers.Integral,
    "table_lr": numbers.Real,
    "head_lr": numbers.Real,
    "requests": numbers.Integral,
    "serve_batch": numbers.Integral,
    "cache_rows": numbers.Integral,
    "retier_every": numbers.Integral,
    "drift": numbers.Real,
    "retier_async": bool,
    "bytes_fp32": numbers.Integral,
    "auc_fp32": numbers.Real,
    "sweep": list,
}

HASH_SWEEP = {
    "ratio_target": numbers.Real,
    "ratio_actual": numbers.Real,
    "pool_slots": numbers.Integral,
    "bytes": numbers.Integral,
    "bytes_combined": numbers.Integral,
    "auc": numbers.Real,
    "auc_gap": numbers.Real,
    "auc_combined": numbers.Real,
    "qps": numbers.Real,
    "steady_qps": numbers.Real,
    "p50_us": numbers.Real,
    "p99_us": numbers.Real,
    "lookups": numbers.Integral,
    "hits": numbers.Integral,
    "cache_hit_rate": numbers.Real,
    "retiers": numbers.Integral,
    **LATENCY_KEYS,
}

# a hashed sweep that never reaches this target ratio has not
# demonstrated the memory bound the backend exists for
HASH_MIN_TOP_RATIO = 100.0


def _validate_hash(rec: dict) -> list[str]:
    """``bench_hash/v1`` (benchmarks/hashed.py): pool-ratio sweep.
    The load-bearing invariants: pool bytes fall STRICTLY as the
    target ratio rises (the compression knob must actually compress),
    the int8-combined pool is smaller than the fp32 pool at every
    ratio, latency percentiles are ordered, and the sweep reaches at
    least ``HASH_MIN_TOP_RATIO`` x."""
    errors: list[str] = []
    _check_keys(rec, HASH_TOP, "top-level", errors)
    entries = _check_sweep(rec, HASH_SWEEP, errors)
    _check_latency(entries, errors)
    ratios = [e.get("ratio_target") for e in entries]
    if len(set(ratios)) != len(ratios):
        errors.append("sweep: duplicate ratio_target entries")
    ok = [e for e in entries
          if _is_num(e.get("ratio_target"))
          and isinstance(e.get("bytes"), numbers.Integral)]
    ok.sort(key=lambda e: e["ratio_target"])
    for lo, hi in zip(ok, ok[1:]):
        if hi["bytes"] >= lo["bytes"]:
            errors.append(
                "sweep: pool bytes must fall strictly as the target "
                f"ratio rises ({lo['ratio_target']:g}x: {lo['bytes']} "
                f"-> {hi['ratio_target']:g}x: {hi['bytes']})")
    if ok and ok[-1]["ratio_target"] < HASH_MIN_TOP_RATIO:
        errors.append(
            f"sweep: top ratio {ok[-1]['ratio_target']:g}x below the "
            f"required {HASH_MIN_TOP_RATIO:g}x")
    bf = rec.get("bytes_fp32")
    for i, e in enumerate(entries):
        b, bc = e.get("bytes"), e.get("bytes_combined")
        if isinstance(b, numbers.Integral) \
                and isinstance(bc, numbers.Integral) and bc >= b:
            errors.append(f"sweep[{i}]: int8-combined bytes {bc} not "
                          f"below fp32 pool bytes {b}")
        ra = e.get("ratio_actual")
        if isinstance(b, numbers.Integral) and b > 0 \
                and isinstance(bf, numbers.Integral) and _is_num(ra) \
                and abs(ra - bf / b) > 0.02 * max(ra, 1.0):
            errors.append(f"sweep[{i}]: ratio_actual {ra} "
                          f"inconsistent with byte counts "
                          f"({bf / b:.2f})")
        if _is_num(e.get("cache_hit_rate")) \
                and not 0.0 <= e["cache_hit_rate"] <= 1.0:
            errors.append(f"sweep[{i}]: cache_hit_rate out of [0, 1]")
    return errors


SCHEMAS = {
    "bench_qps/v1": _validate_qps,
    "bench_hier/v1": _validate_hier,
    "bench_pipeline/v1": _validate_pipeline,
    "bench_kernel/v1": _validate_kernel,
    "bench_fleet/v1": _validate_fleet,
    "bench_hash/v1": _validate_hash,
    "metrics_snapshot/v1": _validate_metrics,
}


def validate(rec: dict) -> list[str]:
    schema = rec.get("schema")
    fn = SCHEMAS.get(schema)
    if fn is None:
        return [f"top-level: schema is {schema!r}, expected one of "
                f"{sorted(SCHEMAS)}"]
    return fn(rec)


def _load_records(path: str) -> list[dict]:
    """One record per file, or one per line for ``.jsonl`` streams."""
    with open(path) as f:
        text = f.read()
    if path.endswith(".jsonl"):
        return [json.loads(line) for line in text.splitlines()
                if line.strip()]
    return [json.loads(text)]


def _committed_manifest() -> tuple[dict[str, tuple[str, str]], str]:
    """Load ``benchmarks.manifest.COMMITTED_BENCH`` by file path (and
    return the repo root), so the gate works regardless of
    PYTHONPATH/cwd."""
    import importlib.util
    import os
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    spec = importlib.util.spec_from_file_location(
        "bench_manifest", os.path.join(root, "benchmarks",
                                       "manifest.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return dict(mod.COMMITTED_BENCH), root


def main() -> int:
    args = sys.argv[1:]
    expected: dict[str, str] = {}
    if "--committed" in args:
        args.remove("--committed")
        manifest, root = _committed_manifest()
        import os
        committed = [os.path.join(root, name) for name in
                     sorted(manifest)]
        expected = {os.path.join(root, name): schema
                    for name, (schema, _) in manifest.items()}
        paths = args + committed
    else:
        paths = args or ["BENCH_qps.json"]
    failed = False
    for path in paths:
        try:
            recs = _load_records(path)
        except (OSError, json.JSONDecodeError) as e:
            print(f"{path}: unreadable: {e}")
            failed = True
            continue
        if not recs:
            print(f"{path}: no records")
            failed = True
            continue
        file_errors = 0
        for ln, rec in enumerate(recs, 1):
            where = f"{path}:{ln}" if len(recs) > 1 else path
            errors = validate(rec)
            want = expected.get(path)
            if want is not None and rec.get("schema") != want:
                errors.append(f"schema is {rec.get('schema')!r}, the "
                              f"committed manifest expects {want!r}")
            for err in errors:
                print(f"{where}: {err}")
            file_errors += len(errors)
        if file_errors:
            failed = True
        else:
            rec = recs[-1]
            sweep = rec.get("sweep")
            if isinstance(sweep, list):
                detail = f"{len(sweep)} sweep entries"
            elif len(recs) > 1:
                detail = f"{len(recs)} records"
            else:
                detail = "single record"
            print(f"{path}: valid {rec['schema']} ({detail})")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
