#!/usr/bin/env python
"""Re-merge ``metrics_snapshot/v1`` JSONL streams and print a table.

    PYTHONPATH=src python tools/summarize_metrics.py run.jsonl [...]

Takes one or many snapshot streams (``--metrics-out`` files from
``launch.serve`` / ``launch.pipeline``, or the per-replica streams from
``launch.fleet``).  Each stream's records are cumulative, so only its
LAST line enters the merge (``repro.obs.fleet.last_snapshot``); across
files the fold is the exact bucket merge (``obs.FleetAggregator`` via
``Histogram.from_snapshot``) — the printed fleet percentiles are the
percentiles of the union latency stream, bit-identical to what a
single process recording every sample would report, NOT a mean of
per-file percentiles.

Output: one row per span/histogram (count, p50/p95/p99 in the
histogram's native unit, ``_us`` for spans), then counters, then
gauges (namespaced ``<source>.<name>`` when merging multiple named
sources).  ``--statsd`` prints the merged registry as statsd line
protocol instead.  docs/observability.md#fleet-aggregation.
"""

from __future__ import annotations

import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.obs import FleetAggregator, last_snapshot  # noqa: E402


def main() -> int:
    ap = argparse.ArgumentParser(
        description="merge metrics_snapshot/v1 streams exactly and "
                    "print per-metric percentiles")
    ap.add_argument("paths", nargs="+", metavar="FILE.jsonl",
                    help="snapshot streams; each contributes its last "
                         "(cumulative) record")
    ap.add_argument("--statsd", action="store_true",
                    help="emit statsd line protocol instead of the "
                         "table")
    args = ap.parse_args()

    snaps = [last_snapshot(p) for p in args.paths]
    agg = FleetAggregator.from_snapshots(snaps)
    merged = agg.merged()

    if args.statsd:
        for line in agg.statsd():
            print(line)
        return 0

    srcs = [s.get("source") or f"r{i}" for i, s in enumerate(snaps)]
    print(f"merged {len(snaps)} snapshot stream(s): {', '.join(srcs)}")
    rows = [(name, h.count, h.percentile(50), h.percentile(95),
             h.percentile(99))
            for name, h in sorted(merged.histograms.items())]
    if rows:
        w = max(len(r[0]) for r in rows)
        print(f"\n{'histogram':<{w}}  {'count':>9}  {'p50':>12}  "
              f"{'p95':>12}  {'p99':>12}")
        for name, count, p50, p95, p99 in rows:
            print(f"{name:<{w}}  {count:>9d}  {p50:>12.1f}  "
                  f"{p95:>12.1f}  {p99:>12.1f}")
    if merged.counters:
        w = max(len(k) for k in merged.counters)
        print(f"\n{'counter':<{w}}  {'total':>12}")
        for name, val in sorted(merged.counters.items()):
            print(f"{name:<{w}}  {val:>12g}")
    if merged.gauges:
        w = max(len(k) for k in merged.gauges)
        print(f"\n{'gauge':<{w}}  {'value':>12}")
        for name, val in sorted(merged.gauges.items()):
            print(f"{name:<{w}}  {val:>12g}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
