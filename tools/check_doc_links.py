#!/usr/bin/env python3
"""Docs link check: relative links and heading anchors must resolve.

Scans README.md and docs/*.md for markdown links ``[text](target)`` and
fails (exit 1) when

  * a relative file target does not exist, or
  * a ``#anchor`` (same-file or ``file.md#anchor``) does not match any
    heading's GitHub-style slug in the target file.

External (``http``/``https``/``mailto``) targets are skipped.  Run from
the repo root: ``python tools/check_doc_links.py`` (CI does).
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$")


def github_slug(heading: str) -> str:
    """GitHub's anchor slug: drop markdown emphasis markers, lowercase,
    keep alphanumerics, hyphens and underscores (GitHub preserves ``_``
    in anchors — headings naming code identifiers rely on it), map each
    space to a hyphen."""
    text = re.sub(r"[`*]", "", heading.strip())
    out = []
    for ch in text.lower():
        if ch.isalnum() or ch in "-_":
            out.append(ch)
        elif ch == " ":
            out.append("-")
    return "".join(out)


def heading_slugs(path: Path) -> set[str]:
    slugs: set[str] = set()
    in_fence = False
    for line in path.read_text(encoding="utf-8").splitlines():
        if line.lstrip().startswith("```"):
            in_fence = not in_fence
            continue
        if in_fence:
            continue
        m = HEADING_RE.match(line)
        if m:
            slugs.add(github_slug(m.group(1)))
    return slugs


def doc_files() -> list[Path]:
    files = [ROOT / "README.md"]
    files += sorted((ROOT / "docs").glob("*.md"))
    return [f for f in files if f.exists()]


def check() -> list[str]:
    errors: list[str] = []
    for doc in doc_files():
        in_fence = False
        for lineno, line in enumerate(
                doc.read_text(encoding="utf-8").splitlines(), 1):
            if line.lstrip().startswith("```"):
                in_fence = not in_fence
                continue
            if in_fence:
                continue
            for target in LINK_RE.findall(line):
                if target.startswith(("http://", "https://", "mailto:")):
                    continue
                where = f"{doc.relative_to(ROOT)}:{lineno}"
                file_part, _, anchor = target.partition("#")
                dest = doc if not file_part else (
                    doc.parent / file_part).resolve()
                if not dest.exists():
                    errors.append(f"{where}: broken file link -> "
                                  f"{target}")
                    continue
                if anchor and dest.suffix == ".md":
                    if anchor not in heading_slugs(dest):
                        errors.append(f"{where}: broken anchor -> "
                                      f"{target}")
    return errors


def main() -> int:
    errors = check()
    for e in errors:
        print(e, file=sys.stderr)
    n_files = len(doc_files())
    if errors:
        print(f"doc link check FAILED: {len(errors)} broken link(s) "
              f"across {n_files} file(s)", file=sys.stderr)
        return 1
    print(f"doc link check OK ({n_files} files)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
