"""Quickstart: SHARK end to end in ~60 seconds on CPU.

  1. train a small DLRM on synthetic click logs with F-Quantization
     (priorities + tier snapping in the train step),
  2. score feature fields with F-Permutation (first-order Taylor),
  3. prune the weakest fields, finetune,
  4. pack the table into the tier-partitioned serving store and serve.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    FQuantConfig,
    assign_tiers,
    auc,
    compression_ratio,
    pack,
    taylor,
)
from repro.core import qat_store as qs
from repro.core.packed_store import lookup as packed_lookup
from repro.core.tiers import plan_thresholds_for_ratio
from repro.data.criteo import CriteoConfig, CriteoSynth
from repro.models import embedding as E
from repro.models import recsys as R
from repro.optim import rowwise_adagrad
from repro.optim.optimizers import apply_updates


def main():
    # ----- data + model -------------------------------------------------
    ds = CriteoSynth(CriteoConfig(num_fields=10, important_fields=5,
                                  num_dense=4, noise=0.3))
    model = R.make_dlrm(R.DLRMConfig(
        cardinalities=tuple(int(c) for c in ds.cards), embed_dim=16,
        num_dense=4, bot_mlp=(32, 16), top_mlp=(64, 1)))
    spec = model.spec
    params = model.init(jax.random.PRNGKey(0))
    print(f"model: DLRM, {spec.num_fields} fields, "
          f"{spec.total_rows:,} embedding rows x {spec.dim}")

    # ----- F-Quantization training (Eq. 5-8) ----------------------------
    fq = FQuantConfig()           # paper defaults; thresholds planned below
    opt = rowwise_adagrad(0.05)
    state = opt.init(params)
    priority = jnp.zeros((spec.total_rows,), jnp.float32)
    key = jax.random.PRNGKey(42)

    @jax.jit
    def train_step(params, state, priority, batch, key, t8, t16):
        def loss(p):
            emb = model.embed(p, batch)
            return model.loss_from_emb(p, emb, batch).mean()
        l, g = jax.value_and_grad(loss)(params)
        upd, state = opt.update(g, state, params)
        params = apply_updates(params, upd)
        cfg = fq._replace(tiers=fq.tiers._replace(t8=t8, t16=t16))
        store = qs.QATStore(params["embed_table"], priority)
        key, sub = jax.random.split(key)
        store = qs.post_step(store, E.globalize(batch["indices"], spec),
                             batch["labels"], cfg, key=sub)
        params = dict(params, embed_table=store.table)
        return params, state, store.priority, key, l

    t8, t16 = -np.inf, -np.inf    # warmup: pure fp32 while priorities form
    for i in range(600):
        if i == 100:              # plan thresholds for a 50% memory budget
            planned = plan_thresholds_for_ratio(priority, spec.dim, 0.5)
            t8, t16 = planned.t8, planned.t16
            print(f"planned thresholds t8={t8:.3g} t16={t16:.3g}")
        b = {k: jnp.asarray(v) for k, v in ds.batch(512, i).items()}
        params, state, priority, key, l = train_step(
            params, state, priority, b, key, t8, t16)
    tiers = assign_tiers(priority, planned)
    print(f"train loss {float(l):.4f}; memory at "
          f"{compression_ratio(tiers, spec.dim):.1%} of fp32")

    # ----- F-Permutation field scores (Eq. 4) ---------------------------
    eval_batches = [{k: jnp.asarray(v) for k, v in
                     ds.batch(512, 9000 + i).items()} for i in range(4)]
    scores, _, _ = taylor.fperm_scores(
        lambda p, b: model.embed(p, b), model.loss_from_emb, params,
        eval_batches)
    order = np.argsort(np.asarray(scores))
    print("field importance (least->most):", order.tolist())
    print("planted-dead fields          :",
          sorted(ds.lossless_fields().tolist()))

    # prune the 3 weakest, finetune briefly
    mask = np.ones(10, np.float32)
    mask[order[:3]] = 0.0
    jmask = jnp.asarray(mask)
    for i in range(150):
        b = {k: jnp.asarray(v) for k, v in ds.batch(512, 700 + i).items()}
        params, state, priority, key, l = train_step(
            params, state, priority, b, key, t8, t16)

    # ----- pack + serve ---------------------------------------------------
    store = qs.QATStore(params["embed_table"], priority)
    packed = pack(store, fq._replace(tiers=planned, stochastic=False))
    print(f"packed store: {packed.nbytes() / 2**20:.1f} MiB "
          f"(fp32 would be {spec.total_rows * spec.dim * 4 / 2**20:.1f})")

    test = {k: jnp.asarray(v) for k, v in ds.batch(4096, 12345).items()}
    emb = packed_lookup(packed, E.globalize(test["indices"], spec))
    emb = emb * jmask[None, :, None]
    logits = model.head(params, emb, test)
    print("serving AUC from the packed store: "
          f"{float(auc(logits, test['labels'])):.4f}")


if __name__ == "__main__":
    main()
