"""Serving driver: batched requests against the tier-packed store.

Simulates the paper's serving deployment: a packed (int8/bf16/fp32)
embedding store behind a DLRM ranking head, processing batched request
streams; reports bytes-per-request vs fp32 (the QPS mechanism) and
latency on this host.  The fused Pallas lookup kernel is exercised on a
slice of traffic (interpret mode on CPU).

Run:  PYTHONPATH=src python examples/serve_quantized.py
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import FQuantConfig, auc, pack
from repro.core import qat_store as qs
from repro.core.packed_store import lookup as packed_lookup
from repro.core.tiers import plan_thresholds_for_ratio
from repro.data.criteo import CriteoConfig, CriteoSynth
from repro.kernels.dequant_bag.ops import packed_bag_lookup
from repro.models import embedding as E
from repro.models import recsys as R
from repro.optim import rowwise_adagrad
from repro.optim.optimizers import apply_updates


def main():
    ds = CriteoSynth(CriteoConfig(num_fields=10, important_fields=5,
                                  num_dense=4, seed=2))
    model = R.make_dlrm(R.DLRMConfig(
        cardinalities=tuple(int(c) for c in ds.cards), embed_dim=16,
        num_dense=4, bot_mlp=(32, 16), top_mlp=(64, 1)))
    spec = model.spec

    # quick train with priorities
    params = model.init(jax.random.PRNGKey(0))
    opt = rowwise_adagrad(0.05)
    state = opt.init(params)
    priority = jnp.zeros((spec.total_rows,), jnp.float32)
    key = jax.random.PRNGKey(3)
    fq = FQuantConfig()

    @jax.jit
    def step(params, state, priority, batch, key, t8, t16):
        def loss(p):
            return model.loss_from_emb(
                p, model.embed(p, batch), batch).mean()
        g = jax.grad(loss)(params)
        upd, state = opt.update(g, state, params)
        params = apply_updates(params, upd)
        cfg = fq._replace(tiers=fq.tiers._replace(t8=t8, t16=t16))
        store = qs.QATStore(params["embed_table"], priority)
        key, sub = jax.random.split(key)
        store = qs.post_step(store, E.globalize(batch["indices"], spec),
                             batch["labels"], cfg, key=sub)
        return dict(params, embed_table=store.table), state, \
            store.priority, key

    t8 = t16 = -np.inf
    for i in range(400):
        if i == 80:
            planned = plan_thresholds_for_ratio(priority, spec.dim, 0.5)
            t8, t16 = planned.t8, planned.t16
        b = {k: jnp.asarray(v) for k, v in ds.batch(512, i).items()}
        params, state, priority, key = step(params, state, priority, b,
                                            key, t8, t16)

    cfg = fq._replace(tiers=planned, stochastic=False)
    store = qs.QATStore(params["embed_table"], priority)
    store = store._replace(table=qs.snap(
        store.table, qs.current_tiers(store, cfg), cfg))
    packed = pack(store, cfg)
    fp32_bytes = spec.total_rows * spec.dim * 4
    print(f"packed store {packed.nbytes()/2**20:.1f} MiB "
          f"({packed.nbytes()/fp32_bytes:.1%} of fp32) | tiers: "
          f"{packed.payload8.shape[0]:,} int8 / "
          f"{packed.payload16.shape[0]:,} bf16 / "
          f"{packed.payload32.shape[0]:,} fp32 rows")

    # ---- serve a request stream -----------------------------------------
    @jax.jit
    def serve(packed, params, batch):
        emb = packed_lookup(packed, E.globalize(batch["indices"], spec))
        return model.head(params, emb, batch)

    lat = []
    all_scores, all_labels = [], []
    for r in range(20):
        batch = {k: jnp.asarray(v)
                 for k, v in ds.batch(512, 40_000 + r).items()}
        t0 = time.perf_counter()
        scores = serve(packed, params, batch)
        scores.block_until_ready()
        lat.append(time.perf_counter() - t0)
        all_scores.append(scores)
        all_labels.append(batch["labels"])
    lat_us = np.array(lat[2:]) * 1e6
    a = float(auc(jnp.concatenate(all_scores), jnp.concatenate(all_labels)))
    print(f"served 20 batches x512 | AUC {a:.4f} | "
          f"p50 {np.percentile(lat_us, 50):.0f}us "
          f"p99 {np.percentile(lat_us, 99):.0f}us (CPU host)")

    # ---- fused Pallas kernel path on one batch (interpret on CPU) -------
    batch = {k: jnp.asarray(v) for k, v in ds.batch(64, 60_000).items()}
    gidx = E.globalize(batch["indices"], spec)
    bags_kernel = packed_bag_lookup(packed, gidx)
    rows = packed_lookup(packed, gidx)
    np.testing.assert_allclose(np.asarray(bags_kernel),
                               np.asarray(rows.sum(axis=1)), rtol=1e-5,
                               atol=1e-5)
    print("fused dequant_bag kernel output verified against serving path")


if __name__ == "__main__":
    main()
