"""End-to-end SHARK compression driver (the paper's production pipeline).

Full Algorithm 1 (iterative prune -> finetune -> evaluate with the
T_accuracy guard) followed by F-Quantization at a target memory budget,
with the combined memory report of Table 4.

Run:  PYTHONPATH=src python examples/compress_dlrm.py [--steps 800]
"""

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    FQuantConfig,
    PruneConfig,
    assign_tiers,
    auc,
    compression_ratio,
    prune_loop,
)
from repro.core.tiers import plan_thresholds_for_ratio
from repro.data.criteo import CriteoConfig, CriteoSynth
from repro.models import recsys as R
from repro.optim import rowwise_adagrad
from repro.optim.optimizers import apply_updates


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=700)
    ap.add_argument("--rate-c", type=float, default=0.55,
                    help="memory target for pruning (fraction kept)")
    ap.add_argument("--t-accuracy", type=float, default=0.9925,
                    help="paper guard: stop below this x base metric")
    args = ap.parse_args()

    ds = CriteoSynth(CriteoConfig(num_fields=12, important_fields=6,
                                  num_dense=4, noise=0.3, seed=1))
    model = R.make_dlrm(R.DLRMConfig(
        cardinalities=tuple(int(c) for c in ds.cards), embed_dim=16,
        num_dense=4, bot_mlp=(32, 16), top_mlp=(64, 1)))
    spec = model.spec
    opt = rowwise_adagrad(0.05)

    @jax.jit
    def train_step(params, state, batch, mask):
        def loss(p):
            emb = model.embed(p, batch, mask)
            return model.loss_from_emb(p, emb, batch).mean()
        g = jax.grad(loss)(params)
        upd, state = opt.update(g, state, params)
        return apply_updates(params, upd), state

    def train(params, steps, mask=None, start=0):
        state = opt.init(params)
        m = jnp.ones(spec.num_fields) if mask is None else mask
        for i in range(steps):
            b = {k: jnp.asarray(v)
                 for k, v in ds.batch(512, start + i).items()}
            params, state = train_step(params, state, b, m)
        return params

    print("== pre-training the base model ==")
    params = train(model.init(jax.random.PRNGKey(0)), args.steps)

    eval_batches = [{k: jnp.asarray(v) for k, v in
                     ds.batch(1024, 50_000 + i).items()} for i in range(8)]

    def eval_metric_fn(p, mask):
        s = jnp.concatenate(
            [model.forward(p, b, mask) for b in eval_batches])
        l = jnp.concatenate([b["labels"] for b in eval_batches])
        return float(auc(s, l))

    def finetune_fn(p, mask, steps):
        return train(p, steps, mask=mask, start=70_000)

    base_auc = eval_metric_fn(params, jnp.ones(spec.num_fields))
    print(f"base AUC {base_auc:.4f}")

    print("== Algorithm 1: F-Permutation pruning ==")
    result = prune_loop(
        params, model.embed, model.loss_from_emb, eval_metric_fn,
        finetune_fn, lambda: eval_batches, spec.table_bytes(),
        PruneConfig(rate_c=args.rate_c, t_accuracy=args.t_accuracy,
                    finetune_steps=100))
    for e in result.log:
        print(f"  iter {e.iteration}: pruned field {e.pruned_field:2d} "
              f"-> AUC {e.metric:.4f}, memory {e.remaining_memory:.1%} "
              f"({e.seconds:.1f}s)")
    print(f"pruned model: AUC {result.final_metric:.4f} "
          f"(guard {args.t_accuracy:.2%} of {result.base_metric:.4f}), "
          f"memory {result.remaining_memory:.1%}")
    print(f"planted-dead fields: {sorted(ds.lossless_fields().tolist())}; "
          f"pruned: {sorted(int(f) for f in result.ranking())}")

    print("== F-Quantization at a 50% budget on the survivors ==")
    from repro.core import qat_store as qs
    from repro.models import embedding as E
    params = result.params
    mask = jnp.asarray(result.field_mask.astype(np.float32))
    priority = jnp.zeros((spec.total_rows,), jnp.float32)
    state = opt.init(params)
    key = jax.random.PRNGKey(7)
    fq = FQuantConfig()
    planned = None
    for i in range(300):
        b = {k: jnp.asarray(v) for k, v in ds.batch(512, 90_000 + i
                                                    ).items()}

        def loss(p):
            emb = model.embed(p, b, mask)
            return model.loss_from_emb(p, emb, b).mean()
        g = jax.grad(loss)(params)
        upd, state = opt.update(g, state, params)
        params = apply_updates(params, upd)
        store = qs.QATStore(params["embed_table"], priority)
        if i == 60:
            planned = plan_thresholds_for_ratio(priority, spec.dim, 0.5)
            fq = fq._replace(tiers=planned)
        key, sub = jax.random.split(key)
        store = qs.post_step(store, E.globalize(b["indices"], spec),
                             b["labels"], fq, key=sub)
        params = dict(params, embed_table=store.table)
        priority = store.priority

    quant_auc = eval_metric_fn(params, mask)
    tiers = assign_tiers(priority, planned)
    quant_ratio = compression_ratio(tiers, spec.dim)
    combined = quant_ratio * result.remaining_memory
    print(f"F-Q AUC {quant_auc:.4f} at {quant_ratio:.1%} precision-memory")
    print(f"== combined (Table 4): {combined:.1%} of baseline embedding "
          f"bytes, AUC {quant_auc:.4f} vs base {base_auc:.4f} ==")


if __name__ == "__main__":
    main()
