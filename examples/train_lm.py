"""LM training with SHARK F-Quantization on the token-embedding table.

Trains a small decoder-only transformer (same architecture family as the
assigned LM configs) on synthetic zipf token streams through the
fault-tolerant loop (checkpoint/restart + NaN guard), with Eq. 7
priorities accumulating on token rows — demonstrating the LM face of the
paper's technique (token frequency == row priority).

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 120]
"""

import argparse
import shutil
import tempfile

import jax
import jax.numpy as jnp

from repro.core import FQuantConfig, assign_tiers, compression_ratio
from repro.core.tiers import plan_thresholds_for_ratio
from repro.data.lm import LMConfig as DataConfig
from repro.data.lm import LMSynth
from repro.models import transformer as T
from repro.optim import adam
from repro.train.loop import LoopConfig, run
from repro.train.steps import FQuantHook, init_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--resume-demo", action="store_true",
                    help="interrupt at 2/3 and resume from checkpoint")
    args = ap.parse_args()

    cfg = T.LMConfig(name="lm-demo", n_layers=4, d_model=128, n_heads=8,
                     n_kv_heads=4, head_dim=16, d_ff=512, vocab=8192,
                     tie_embeddings=True, max_seq=128)
    data = LMSynth(DataConfig(vocab=8192, seq_len=128))
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    n_params = sum(x.size for x in jax.tree_util.tree_leaves(params))
    print(f"transformer: {cfg.n_layers}L d{cfg.d_model} "
          f"{n_params/1e6:.1f}M params, vocab {cfg.vocab}")

    optimizer = adam(3e-3)
    hook = FQuantHook(
        cfg=FQuantConfig(),
        table_path="embed",
        indices_fn=lambda b: b["tokens"],
        labels_fn=lambda b: jnp.ones(b["tokens"].shape[0], jnp.float32))
    step = jax.jit(make_train_step(
        lambda p, b: T.lm_loss(p, cfg, b["tokens"]), optimizer, hook))
    state = init_state(params, optimizer, hook)

    def batch_fn(i):
        return {k: jnp.asarray(v) for k, v in data.batch(8, i).items()}

    ckpt_dir = tempfile.mkdtemp(prefix="lm_demo_")
    loop_cfg = LoopConfig(total_steps=args.steps, ckpt_every=40,
                          ckpt_dir=ckpt_dir, log_every=20)

    def cb(step_i, metrics):
        print(f"  step {step_i:4d} loss {float(metrics['loss']):.3f}")

    if args.resume_demo:
        first = LoopConfig(total_steps=args.steps * 2 // 3, ckpt_every=40,
                           ckpt_dir=ckpt_dir, log_every=20)
        run(state, step, batch_fn, first, cb)
        print("-- simulated preemption; relaunching --")
    res = run(state, step, batch_fn, loop_cfg, cb)
    if res.resumed_from:
        print(f"resumed from checkpointed step {res.resumed_from}")
    print(f"loss {res.losses[0] if res.losses else float('nan'):.3f} -> "
          f"{res.losses[-1]:.3f} over {res.steps_run} steps "
          f"({res.stragglers} straggler steps, {res.nan_skips} NaN skips)")

    # token-table tier report (zipf head -> fp32, tail -> int8)
    pri = res.state.priority
    planned = plan_thresholds_for_ratio(pri, cfg.d_model, 0.5)
    tiers = assign_tiers(pri, planned)
    print("token-embedding memory at thresholds for 50% budget: "
          f"{compression_ratio(tiers, cfg.d_model):.1%} of fp32")
    shutil.rmtree(ckpt_dir, ignore_errors=True)


if __name__ == "__main__":
    main()
